"""Fleet serving layer (ISSUE 7 tentpole): seeded arrivals, profile/model
consistency, locality-aware placement, keep-warm economics, autoscaling,
and crash re-routing — all deterministic per seed."""
import math

import numpy as np
import pytest

from repro.core import (
    HierarchicalPool,
    PoolMaster,
    SnapshotReader,
    StateImage,
)
from repro.core.pagestore import PAGE_SIZE
from repro.core.snapshot import exclusive_cxl_bytes
from repro.fleet import (
    MODE_COLD,
    MODE_JOIN,
    MODE_WARM,
    FleetDriver,
    FunctionType,
    PlacementScheduler,
    QueueAutoscaler,
    RestoreProfile,
    Trace,
    generate_trace,
    onoff_arrivals,
    poisson_arrivals,
    profile_reader,
    synthesize_fleet,
    zipf_rates,
)
from repro.serve.strategies import (
    KEEPWARM_BYTE_S_COST,
    WARM_RESUME_S,
    keepwarm_economics,
    modeled_concurrent_restore_s,
)
from repro.sim.clock import VirtualClock


def synthetic_profile(fn_id=0, hot=4 << 20, cold=8 << 20, shared=2 << 20):
    return RestoreProfile(
        name=f"fn{fn_id}", version=1, total_pages=(hot + cold) // PAGE_SIZE,
        hot_bytes=hot, cold_bytes=cold,
        meta_terms=((4e-7 + 4096 / 50e9, 4096),
                    (4e-7 + 8192 / 50e9, 8192)),
        flush_s=1e-5, hot_serial_s=hot / 50e9,
        hot_chunks=max(1, hot // (64 * PAGE_SIZE)),
        hot_install_s=3e-5, zero_install_s=1e-6,
        cold_serial_s=cold / 12.5e9, cold_install_s=5e-5,
        shared_base_bytes=shared, exclusive_bytes=hot - shared)


def small_stack(n_types=6, n_bases=2, total_rps=200.0):
    fleet = synthesize_fleet(n_types, n_bases, total_rps, seed=7)
    profiles = {f.fn_id: synthetic_profile(f.fn_id) for f in fleet}
    return fleet, profiles


# -- arrivals -----------------------------------------------------------------

def test_trace_bit_deterministic_per_seed():
    fleet, _ = small_stack()
    a = generate_trace(fleet, 10.0, seed=1)
    b = generate_trace(fleet, 10.0, seed=1)
    c = generate_trace(fleet, 10.0, seed=2)
    assert np.array_equal(a.t, b.t) and np.array_equal(a.fn, b.fn)
    assert np.array_equal(a.compute_s, b.compute_s)
    assert not np.array_equal(a.t, c.t)


def test_trace_independent_of_fleet_order():
    """Per-fn SeedSequence((seed, fn_id)) makes the merged trace identical
    no matter the order function types are generated in."""
    fleet, _ = small_stack()
    a = generate_trace(fleet, 10.0, seed=3)
    b = generate_trace(list(reversed(fleet)), 10.0, seed=3)
    assert np.array_equal(a.t, b.t) and np.array_equal(a.fn, b.fn)


def test_arrival_means_match_offered_load():
    rng = np.random.default_rng(0)
    n = poisson_arrivals(rng, 50.0, 200.0).size
    assert abs(n - 10_000) < 500
    # ON/OFF is duty-cycle normalized: long-run mean is preserved
    rng = np.random.default_rng(0)
    n = onoff_arrivals(rng, 50.0, 400.0, mean_on_s=2.0, mean_off_s=8.0).size
    assert abs(n - 20_000) < 2_500


def test_zipf_rates_heavy_tail():
    r = zipf_rates(100, 1000.0, alpha=1.1)
    assert math.isclose(r.sum(), 1000.0, rel_tol=1e-9)
    assert r[0] > 20 * r[50]             # heavy head
    assert np.all(np.diff(r) <= 0)


def test_trace_sorted_and_typed():
    fleet, _ = small_stack()
    tr = generate_trace(fleet, 5.0, seed=0)
    assert np.all(np.diff(tr.t) >= 0)
    assert tr.fn.dtype == np.int32 and np.all(tr.compute_s > 0)


# -- restore profiles vs the analytic model -----------------------------------

def test_profile_reproduces_restore_model_exactly():
    """profile_reader + cold_start_s must be bit-identical to
    modeled_concurrent_restore_s for a REAL published snapshot, across
    concurrency levels."""
    rng = np.random.default_rng(0)
    pool = HierarchicalPool(cxl_capacity=64 << 20, rdma_capacity=256 << 20)
    master = PoolMaster(pool, dedup=True)
    base = rng.integers(1, 255, 32 * PAGE_SIZE, dtype=np.int64).astype(np.uint8)
    for v in range(3):
        w = base.copy()
        w[v * PAGE_SIZE:(v + 1) * PAGE_SIZE] = \
            rng.integers(1, 255, PAGE_SIZE).astype(np.uint8)
        img = StateImage.build({
            "w": w,
            "cold": rng.integers(1, 255, 16 * PAGE_SIZE).astype(np.uint8),
            "z": np.zeros(8 * PAGE_SIZE, np.uint8),
        })
        master.publish(f"v{v}", img,
                       list(range(img.manifest.by_name()["w"].page_count)))
    for v in range(3):
        entry = master.catalog.find(f"v{v}")
        r = entry.regions
        reader = SnapshotReader(r, pool.host_view(f"p{v}"), pool.rdma)
        excl = exclusive_cxl_bytes(pool, r)
        prof = profile_reader(
            reader, shared_base_bytes=r.n_hot * PAGE_SIZE - excl,
            exclusive_bytes=excl)
        for conc in (1, 2, 8):
            assert prof.cold_start_s(conc) == \
                modeled_concurrent_restore_s(reader, conc)
        # variants share the base -> a real shared fraction for placement
        if v > 0:
            assert 0 < prof.shared_base_bytes <= r.n_hot * PAGE_SIZE
        assert prof.install_only_s() < prof.cold_start_s(1)
        assert prof.cold_start_s(1, overlap_frac=0.9) < prof.cold_start_s(1)
        assert prof.scaled(4.0).hot_bytes == 4 * prof.hot_bytes


def test_profile_contention_and_overlap_monotone():
    p = synthetic_profile()
    assert p.cold_start_s(8) > p.cold_start_s(2) > p.cold_start_s(1)
    assert p.cold_start_s(1, 1.0) < p.cold_start_s(1, 0.5) < p.cold_start_s(1)
    assert p.cold_start_s(4, joined=True) == p.cold_start_s(1, joined=True) \
        or p.cold_start_s(4, joined=True) >= p.cold_start_s(1, joined=True)


# -- keep-warm economics ------------------------------------------------------

def test_keepwarm_break_even_matches_prices():
    restore_s, resident = 20e-3, 256 << 20
    econ = keepwarm_economics(restore_s, 1.0, resident)
    benefit = restore_s - WARM_RESUME_S
    assert math.isclose(econ["benefit_s"], benefit, rel_tol=1e-12)
    assert math.isclose(econ["break_even_gap_s"],
                        benefit / (resident * KEEPWARM_BYTE_S_COST),
                        rel_tol=1e-12)
    gap = econ["break_even_gap_s"]
    assert keepwarm_economics(restore_s, gap * 0.9, resident)["worthwhile"]
    assert not keepwarm_economics(restore_s, gap * 1.1, resident)["worthwhile"]
    # a restore faster than a warm resume is never worth holding for
    assert not keepwarm_economics(WARM_RESUME_S / 2, 1e-6, resident)["worthwhile"]


def test_driver_keepwarm_hit_and_expiry():
    """Back-to-back invocations of a keep-warm-worthy function: the second
    within the expected gap resumes warm; after expiry it restores cold."""
    fn = FunctionType(0, "fn0", 0, rate_rps=100.0, pattern="poisson",
                      compute_mean_s=0.01)
    prof = synthetic_profile(hot=256 << 20, cold=128 << 20)
    econ = keepwarm_economics(prof.cold_start_s(1), 1.0 / fn.rate_rps,
                              prof.hot_bytes + prof.cold_bytes)
    assert econ["worthwhile"], "test premise: this fn should be held warm"
    mk = lambda ts: Trace(np.array(ts), np.zeros(len(ts), np.int32),
                          np.full(len(ts), 0.01))
    done0 = prof.cold_start_s(1) + 0.01  # first invocation completes here
    gap = 1.0 / fn.rate_rps              # expected inter-arrival = hold window
    # second arrival lands inside the hold window after completion
    t2 = done0 + 0.5 * gap
    d = FleetDriver([fn], {0: prof}, policy="locality", seed=0, n_hosts=1,
                    clock=VirtualClock())
    r = d.run(mk([0.0, t2]))
    assert r.mode[0] == MODE_COLD and r.mode[1] == MODE_WARM
    assert r.counters["warm_hits"] == 1
    assert (r.ready_s[1] - t2) == pytest.approx(WARM_RESUME_S)
    # second arrival lands far beyond the window -> the instance expired
    d = FleetDriver([fn], {0: prof}, policy="locality", seed=0, n_hosts=1,
                    clock=VirtualClock())
    r = d.run(mk([0.0, done0 + 10 * gap]))
    assert r.mode[1] == MODE_COLD
    assert r.counters["keepwarm_expired"] >= 1


# -- placement ----------------------------------------------------------------

def test_locality_joins_active_group():
    fn = FunctionType(0, "fn0", 0, 10.0, "poisson", 0.5)
    prof = synthetic_profile(hot=256 << 20, cold=128 << 20)
    tr = Trace(np.array([0.0, 1e-4, 2e-4]), np.zeros(3, np.int32),
               np.full(3, 0.5))
    d = FleetDriver([fn], {0: prof}, policy="locality", seed=0, n_hosts=4,
                    clock=VirtualClock(), keep_warm=False)
    r = d.run(tr)
    assert r.mode[0] == MODE_COLD
    assert list(r.mode[1:]) == [MODE_JOIN, MODE_JOIN]
    assert len(set(r.host.tolist())) == 1, "fan-out group on one host"
    # joiners finish with the group's shared reads, not serially after it
    assert r.ready_s[2] <= r.ready_s[0] + prof.install_only_s() + 1e-9


def test_locality_prefers_overlap_host():
    """A variant restores faster on the host whose base group is resident;
    the scheduler must route it there."""
    f0 = FunctionType(0, "fn0", 0, 10.0, "poisson", 10.0)
    f1 = FunctionType(1, "fn1", 0, 10.0, "poisson", 10.0)   # same base group
    p0 = synthetic_profile(0, hot=256 << 20, cold=0, shared=192 << 20)
    p1 = synthetic_profile(1, hot=256 << 20, cold=0, shared=192 << 20)
    tr = Trace(np.array([0.0, 1.0]), np.array([0, 1], np.int32),
               np.array([10.0, 10.0]))
    d = FleetDriver([f0, f1], {0: p0, 1: p1}, policy="locality", seed=0,
                    n_hosts=4, clock=VirtualClock(), keep_warm=False)
    r = d.run(tr)
    assert r.host[1] == r.host[0], "variant routed to base-resident host"
    # and its restore was overlap-discounted vs a cold host's
    cold = p1.cold_start_s(1)
    got = r.ready_s[1] - 1.0
    assert got < cold
    assert got == pytest.approx(p1.cold_start_s(1, 192 / 256))


def test_policies_deterministic_and_distinct():
    fleet, profiles = small_stack(n_types=12, n_bases=3, total_rps=500.0)
    tr = generate_trace(fleet, 6.0, seed=5)
    outs = {}
    for policy in ("locality", "random", "round_robin"):
        runs = []
        for _ in range(2):
            d = FleetDriver(fleet, profiles, policy=policy, seed=5,
                            n_hosts=4, slots_per_host=8,
                            clock=VirtualClock(),
                            autoscaler=QueueAutoscaler(min_hosts=4,
                                                       max_hosts=16))
            runs.append(d.run(tr))
        a, b = runs
        assert np.array_equal(a.host, b.host)
        assert np.array_equal(a.mode, b.mode)
        assert np.array_equal(a.ready_s, b.ready_s, equal_nan=True)
        assert np.array_equal(a.done_s, b.done_s, equal_nan=True)
        outs[policy] = a
    assert not np.array_equal(outs["locality"].host, outs["random"].host)


def test_scheduler_rejects_unknown_policy():
    with pytest.raises(ValueError):
        PlacementScheduler("best-fit")


# -- autoscaling --------------------------------------------------------------

def test_autoscaler_hysteresis_and_cooldown():
    a = QueueAutoscaler(min_hosts=2, max_hosts=16, up_queue_per_host=4.0,
                        down_queue_per_host=1.0, cooldown_s=5.0)
    assert a.decide(0.0, queued=100, n_alive=4) > 0
    assert a.decide(1.0, queued=100, n_alive=5) == 0     # cooldown holds
    assert a.decide(6.0, queued=100, n_alive=5) > 0
    assert a.decide(20.0, queued=10, n_alive=8) == 0     # between thresholds
    assert a.decide(30.0, queued=0, n_alive=8) < 0
    assert a.decide(40.0, queued=0, n_alive=2) == 0      # at min_hosts
    assert a.decide(50.0, queued=10**6, n_alive=16) == 0  # at max_hosts


def test_driver_scales_up_under_burst():
    fleet, profiles = small_stack(n_types=4, n_bases=2, total_rps=2000.0)
    tr = generate_trace(fleet, 4.0, seed=1)
    d = FleetDriver(fleet, profiles, policy="locality", seed=1, n_hosts=2,
                    slots_per_host=8, clock=VirtualClock(),
                    autoscaler=QueueAutoscaler(min_hosts=2, max_hosts=64,
                                               cooldown_s=0.25))
    r = d.run(tr)
    assert r.counters["scale_ups"] >= 1
    assert r.host_peak > 2
    assert int((~np.isnan(r.done_s)).sum()) == len(tr)


# -- crash re-routing ---------------------------------------------------------

def test_crash_mid_burst_reroutes_and_completes():
    fleet, profiles = small_stack(n_types=8, n_bases=2, total_rps=800.0)
    tr = generate_trace(fleet, 6.0, seed=9)
    d = FleetDriver(fleet, profiles, policy="locality", seed=9, n_hosts=3,
                    slots_per_host=8, clock=VirtualClock(),
                    autoscaler=QueueAutoscaler(min_hosts=3, max_hosts=32),
                    crash_at=[(1.5, 0)])
    r = d.run(tr)
    assert r.counters["crashes"] == 1
    assert r.counters["crash_requeued"] >= 1
    # every invocation still completes, none on the dead host after t=1.5
    assert int((~np.isnan(r.done_s)).sum()) == len(tr)
    rerouted = r.restarts > 0
    assert rerouted.any()
    assert np.all(r.host[rerouted] != 0)
    assert np.all(r.done_s[rerouted] >= 1.5)


def test_crash_rerouted_restores_preserve_pool_invariants():
    """Fleet-level crash re-routing on top of the REAL pool: a host dies
    mid-burst with restores in flight, the same work is re-issued against a
    surviving host, and the coherence invariants (I1-I6) stay clean — the
    SimCluster's InvariantChecker validates them after every step, and any
    leaked refcounts from the crashed host are accounted, not drifted."""
    from repro.sim.cluster import SimCluster

    sim = SimCluster(n_hosts=3, seed=9)
    sim.publish("fnA", 1.0)
    sim.publish("fnB", 2.0)
    # a burst of fan-out restores spread over two hosts
    for k in range(3):
        sim.add_program(f"rA{k}", sim.restore_program(f"h{k % 2}", "fnA"))
        sim.add_program(f"rB{k}", sim.restore_program(f"h{k % 2}", "fnB"))
    steps = 0
    rerouted = False
    while sim.step():
        steps += 1
        if steps == 8 and not rerouted:
            # h0 crashes: its in-flight restores die (their borrows leak,
            # tracked as orphans); re-route the lost work to h1
            rerouted = True
            for prog in ("rA0", "rA2", "rB0", "rB2"):
                sim.kill_program(prog)
            sim.add_program("rA0b", sim.restore_program("h1", "fnA"))
            sim.add_program("rA2b", sim.restore_program("h1", "fnA"))
            sim.add_program("rB0b", sim.restore_program("h1", "fnB"))
            sim.add_program("rB2b", sim.restore_program("h1", "fnB"))
        if steps > 50_000:
            pytest.fail("sim did not converge")
    assert rerouted and any(e.startswith("crashed:") for e in sim.events)
    done = [(r["host"], r["name"]) for r in sim.restored]
    # re-routed work completed on the survivor, bit-verified by the sim
    assert done.count(("h1", "fnA")) >= 3    # rA1 + rA0b + rA2b
    assert done.count(("h1", "fnB")) >= 3    # rB1 + rB0b + rB2b
    # every completed restore was byte-identical (restore_program raises
    # otherwise); run the checker once more on the final state
    sim.checker.check_all()
    assert len(sim.orphaned_records) <= 4
