"""Content-addressed dedup benchmark (ISSUE 5 acceptance).

A serverless fleet restores many snapshots of near-identical images — the
same base model fine-tuned into N variants (exactly what ``configs/`` +
``models/model_zoo.py`` emulate, shrunk to synthetic pages here).  Without
dedup every publish stores its own copy of the shared base pages, so the
PR-4 ``CXLCapacityManager`` demotes/degrades most of the fleet at any
realistic budget.  With the content-addressed store a variant's marginal
CXL cost is its DELTA pages plus metadata, so the same budget keeps a
multiple of the fleet hot.

Two pods with the SAME CXL budget publish the SAME variant fleet — one with
``dedup=True``, one without.  Reported:

* **effective-capacity multiplier** — snapshots resident with their full
  hot set (never demoted/degraded) under dedup vs baseline; the acceptance
  bar is >= 1.5x;
* **unique-byte ratio** — physical store bytes / logical fleet bytes;
* **bit-identical restores** — every variant in BOTH pods is fully
  restored through the production serving path and byte-compared;
* **modeled publish/restore costs** — ``strategies.dedup_publish_cost_s``
  over the measured unique counts, the analytic restore model over the
  dedup layout, and the ``dedup_economics`` break-even verdict;
* **I6 spot-check** — at the end, each store's refcounts must equal the
  catalog's live offset pointers exactly.

All compared keys are modeled/deterministic (fixed default seed; CI's
regression gate holds them to ±10%).  Results land in
``experiments/dedup_bench.json`` (full) or ``dedup_bench_quick.json``
(``--quick`` CI smoke).
"""
from __future__ import annotations

import argparse
import json
import os
from pathlib import Path

import numpy as np

from repro.core import (
    HierarchicalPool,
    Instance,
    PoolMaster,
    RestoreEngine,
    SnapshotReader,
    StateImage,
    decode_dedup_offsets,
)
from repro.core.pagestore import PAGE_SIZE
from repro.core.pool import TIER_CXL, TIER_RDMA
from repro.serve.strategies import (
    HOT_CHUNK_PAGES,
    baseline_publish_cost_s,
    dedup_economics,
    dedup_publish_cost_s,
    modeled_concurrent_restore_s,
)

OUT = Path(__file__).resolve().parents[1] / "experiments"
SEED = int(os.environ.get("AQUIFER_SIM_SEED", "0"))


def make_fleet(n_variants: int, hot_pages: int, cold_pages: int,
               zero_pages: int, delta_pages: int, seed: int = SEED):
    """N fine-tuned variants: shared base weights + per-variant delta rows +
    per-variant cold arena (deltas and arenas are variant-unique)."""
    rng = np.random.default_rng(seed)
    base = rng.integers(1, 255, hot_pages * PAGE_SIZE, dtype=np.int64).astype(np.uint8)
    fleet = []
    for v in range(n_variants):
        w = base.copy()
        lo = (v * delta_pages) % hot_pages
        for d in range(delta_pages):
            p = (lo + d) % hot_pages
            w[p * PAGE_SIZE : (p + 1) * PAGE_SIZE] = \
                rng.integers(1, 255, PAGE_SIZE).astype(np.uint8)
        img = StateImage.build({
            "w": w,
            "cold": rng.integers(1, 255, cold_pages * PAGE_SIZE).astype(np.uint8),
            "z": np.zeros(zero_pages * PAGE_SIZE, np.uint8),
        })
        fleet.append(img)
    return fleet


def restore_and_verify(pool, master, name, img):
    """Full production restore (borrow → flush → extent-walk install) and
    byte-compare; returns (bit_identical, executed modeled seconds)."""
    borrow = master.catalog.borrow(name)
    assert borrow is not None, f"borrow of {name} failed"
    try:
        reader = SnapshotReader(borrow.regions, pool.host_view(f"r-{name}"),
                                pool.rdma)
        reader.invalidate_cxl()
        inst = Instance(StateImage.empty_like(img.manifest))
        eng = RestoreEngine(reader, inst, rdma_engine=None)
        eng.install_all_sync()
        ok = bool(inst.all_present()
                  and np.array_equal(inst.image.buf, img.buf))
        return ok, float(inst.ledger.total())
    finally:
        borrow.release()


def run_pod(fleet, budget_bytes, dedup: bool):
    """Publish the whole fleet into one budgeted pod; restore + verify all."""
    pool = HierarchicalPool(cxl_capacity=1 << 30, rdma_capacity=1 << 30)
    master = PoolMaster(pool, cxl_budget=budget_bytes, dedup=dedup)
    publishes = []
    for v, img in enumerate(fleet):
        ws = list(range(img.manifest.by_name()["w"].page_count))
        before_hot = pool.dedup_cxl.unique_pages()
        before_cold = pool.dedup_rdma.unique_pages()
        regions = master.publish(f"v{v}", img, ws)
        publishes.append({
            "n_hot": regions.n_hot, "n_cold": regions.n_cold,
            "new_unique_hot": pool.dedup_cxl.unique_pages() - before_hot,
            "new_unique_cold": pool.dedup_rdma.unique_pages() - before_cold,
        })
    full_hot = fleet[0].manifest.by_name()["w"].page_count
    resident = sum(1 for e in master.catalog.entries
                   if e.regions is not None and e.regions.n_hot == full_hot)
    restores_ok, exec_restore_s = [], 0.0
    sample_reader = None
    for v, img in enumerate(fleet):
        ok, t = restore_and_verify(pool, master, f"v{v}", img)
        restores_ok.append(ok)
        exec_restore_s += t
    # analytic restore model over a RESIDENT snapshot's actual layout
    for e in master.catalog.entries:
        if e.regions is not None and e.regions.n_hot == full_hot:
            sample_reader = SnapshotReader(e.regions, pool.host_view("model"),
                                           pool.rdma)
            break
    restore_modeled_s = (modeled_concurrent_restore_s(sample_reader, 1)
                         if sample_reader is not None else 0.0)
    report = master.capacity.report()
    return {
        "pool": pool, "master": master, "publishes": publishes,
        "resident_full_hot": resident,
        "demotions": report["demotions"], "degraded": report["degraded"],
        "shared_skips": report["shared_skips"],
        "budget_in_use": report["in_use"],
        "all_bit_identical": bool(all(restores_ok)),
        "exec_restore_total_s": exec_restore_s,
        "restore_modeled_s": restore_modeled_s,
        "sample_reader": sample_reader,
    }


def i6_spot_check(pool, master) -> bool:
    """Store refcounts == live catalog offset pointers, per tier."""
    regions = [e.regions for e in master.catalog.entries
               if e.regions is not None and e.regions.dedup]
    for store, tag in ((pool.dedup_cxl, TIER_CXL), (pool.dedup_rdma, TIER_RDMA)):
        expected = {}
        for r in regions:
            uniq, counts = np.unique(decode_dedup_offsets(pool, r, tag),
                                     return_counts=True)
            for off, k in zip(uniq, counts):
                expected[int(off)] = expected.get(int(off), 0) + int(k)
        if expected != store.refcounts():
            return False
    return True


def count_extents(reader):
    n_hot_ext = sum(1 for _ in reader.iter_hot_extents(HOT_CHUNK_PAGES))
    n_cold_ext = sum(1 for _ in reader.iter_cold_extents())
    return n_hot_ext, n_cold_ext


def run(quick: bool = False) -> dict:
    if quick:
        n_variants, hot, cold, zero, delta = 8, 64, 32, 16, 4
    else:
        n_variants, hot, cold, zero, delta = 24, 256, 128, 64, 12
    fleet = make_fleet(n_variants, hot, cold, zero, delta)
    # per-snapshot private CXL need ≈ metadata (2 pages) + full hot set;
    # budget fits ~1/3 of the fleet without dedup
    per_snapshot = (2 + hot) * PAGE_SIZE
    budget = (n_variants // 3) * per_snapshot

    ded = run_pod(fleet, budget, dedup=True)
    base = run_pod(fleet, budget, dedup=False)

    pool = ded["pool"]
    cxl_rep = pool.dedup_cxl.report()
    rdma_rep = pool.dedup_rdma.report()
    logical = cxl_rep["logical_bytes"] + rdma_rep["logical_bytes"]
    unique = cxl_rep["unique_bytes"] + rdma_rep["unique_bytes"]

    # modeled publish costs over the measured PER-TIER unique counts
    ded_publish_s = sum(
        dedup_publish_cost_s(p["n_hot"], p["n_cold"],
                             p["new_unique_hot"], p["new_unique_cold"])
        for p in ded["publishes"])
    base_publish_s = sum(baseline_publish_cost_s(p["n_hot"], p["n_cold"])
                         for p in base["publishes"])

    # fragmentation penalty of the dedup layout, from a resident reader
    econ = None
    if ded["sample_reader"] is not None:
        n_hot_ext, n_cold_ext = count_extents(ded["sample_reader"])
        contiguous_hot_ext = -(-hot // HOT_CHUNK_PAGES)
        econ = dedup_economics(
            n_hot=n_variants * hot, n_cold=n_variants * cold,
            n_hot_unique=cxl_rep["unique_pages"],
            n_cold_unique=rdma_rep["unique_pages"],
            n_extra_hot_extents=max(0, n_hot_ext - contiguous_hot_ext),
            n_extra_cold_extents=max(0, n_cold_ext - 1),
            expected_restores=64)

    multiplier = (ded["resident_full_hot"] / base["resident_full_hot"]
                  if base["resident_full_hot"] else float(ded["resident_full_hot"]))
    criteria = {
        "capacity_x_ge_1_5": bool(multiplier >= 1.5),
        "all_restores_bit_identical": bool(ded["all_bit_identical"]
                                           and base["all_bit_identical"]),
        "i6_consistent": i6_spot_check(pool, ded["master"]),
        "dedup_worthwhile": bool(econ is None or econ["worthwhile"]),
    }
    drop = ("pool", "master", "publishes", "sample_reader")
    out = {
        "quick": quick, "seed": SEED,
        "fleet": {"n_variants": n_variants, "hot_pages": hot,
                  "cold_pages": cold, "zero_pages": zero,
                  "delta_pages": delta, "budget_bytes": budget,
                  "per_snapshot_cxl_bytes": per_snapshot},
        "dedup": {**{k: v for k, v in ded.items() if k not in drop},
                  "unique_byte_ratio": unique / logical if logical else 1.0,
                  "unique_bytes": unique, "logical_bytes": logical,
                  "publish_modeled_s": ded_publish_s,
                  "store_cxl": cxl_rep, "store_rdma": rdma_rep,
                  "economics": econ},
        "baseline": {**{k: v for k, v in base.items() if k not in drop},
                     "publish_modeled_s": base_publish_s},
        "effective_capacity_x": multiplier,
        "criteria": criteria,
    }
    OUT.mkdir(exist_ok=True)
    name = "dedup_bench_quick.json" if quick else "dedup_bench.json"
    (OUT / name).write_text(json.dumps(out, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI smoke (small fleet)")
    args = ap.parse_args()
    out = run(quick=args.quick)
    f, d, b = out["fleet"], out["dedup"], out["baseline"]
    print(f"fleet: {f['n_variants']} variants x {f['hot_pages']} hot pages, "
          f"budget {f['budget_bytes'] >> 10} KiB")
    print(f"resident with full hot set: dedup {d['resident_full_hot']} vs "
          f"baseline {b['resident_full_hot']} "
          f"-> {out['effective_capacity_x']:.2f}x effective capacity")
    print(f"unique-byte ratio: {d['unique_byte_ratio']:.3f} "
          f"({d['unique_bytes'] >> 10} KiB physical / "
          f"{d['logical_bytes'] >> 10} KiB logical)")
    print(f"publish modeled: dedup {d['publish_modeled_s']*1e3:.3f} ms vs "
          f"baseline {b['publish_modeled_s']*1e3:.3f} ms; restore modeled "
          f"{d['restore_modeled_s']*1e3:.3f} ms vs {b['restore_modeled_s']*1e3:.3f} ms")
    if d["economics"]:
        print(f"economics: net {d['economics']['net_s']:.4f} s over "
              f"{int(d['economics']['expected_restores'])} restores "
              f"({'worthwhile' if d['economics']['worthwhile'] else 'NOT worthwhile'})")
    ok = all(out["criteria"].values())
    print(f"criteria: {out['criteria']}  ->  {'PASS' if ok else 'FAIL'}")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
