"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Order: characterization (Fig 3) → run lengths (Fig 4) → breakdown (Fig 6) →
scalability (Fig 7) → kernel bench. Results land in experiments/*.json and a
combined experiments/bench_summary.json.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

OUT = Path(__file__).resolve().parents[1] / "experiments"


def main() -> int:
    from . import breakdown, characterization, kernel_bench, runlength, scalability

    summary = {}
    t0 = time.perf_counter()

    print("=" * 72)
    print("Fig 3 analogue — snapshot image composition")
    print("=" * 72)
    characterization.main()
    summary["characterization"] = json.loads((OUT / "characterization.json").read_text())["average"]

    print("\n" + "=" * 72)
    print("Fig 4 analogue — hot-set run-length distribution")
    print("=" * 72)
    runlength.main()
    summary["runlength"] = json.loads((OUT / "runlength.json").read_text())["aggregate"]

    print("\n" + "=" * 72)
    print("Fig 6 analogue — invocation breakdown (chameleon @32)")
    print("=" * 72)
    breakdown.main()
    b = json.loads((OUT / "breakdown.json").read_text())
    summary["breakdown"] = {
        "speedup_vs_firecracker": b["speedup_vs_firecracker"],
        "speedup_vs_faasnap": b["speedup_vs_faasnap"],
        "restore_bit_identical": b["restore_bit_identical"],
    }

    print("\n" + "=" * 72)
    print("Fig 7 analogue — scalability 1..32 + headline geomeans")
    print("=" * 72)
    scalability.main()
    summary["scalability"] = json.loads((OUT / "scalability.json").read_text())["geomean_speedups_at_32"]

    print("\n" + "=" * 72)
    print("kernel bench — snapshot-pipeline kernels")
    print("=" * 72)
    kernel_bench.main()

    summary["wall_s"] = time.perf_counter() - t0
    (OUT / "bench_summary.json").write_text(json.dumps(summary, indent=2))
    print(f"\nall benchmarks done in {summary['wall_s']:.1f}s -> experiments/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
