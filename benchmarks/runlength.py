"""Fig. 4 analogue: CDF of contiguous run lengths within the hot working set
(+ the mmap-vs-uffd.copy install-cost comparison from §2.3.4)."""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.core.pagestore import runs_from_pages
from repro.core.pool import UFFD_COPY_PER_PAGE_S
from repro.core.serving import mmap_install_cost
from repro.core.snapshot import classify_pages
from .workloads import all_workloads, get_workload

OUT = Path(__file__).resolve().parents[1] / "experiments"


def run() -> dict:
    rows = []
    all_lens = []
    for name in all_workloads():
        bw = get_workload(name)
        classes = classify_pages(bw.image, bw.profile.working_set)
        hot = classes.hot_pages.tolist()
        runs = runs_from_pages(hot)
        lens = np.asarray([n for _, n in runs], dtype=np.float64)
        all_lens.extend(lens.tolist())
        mmap_cost = mmap_install_cost(hot)   # per-page term + per-range syscalls
        uffd_cost = len(hot) * UFFD_COPY_PER_PAGE_S
        rows.append({
            "workload": name,
            "n_hot_pages": len(hot),
            "n_runs": int(lens.size),
            "mean_run": float(lens.mean()) if lens.size else 0.0,
            "frac_runs_lt4": float((lens < 4).mean()) if lens.size else 0.0,
            "mmap_install_s": mmap_cost,
            "uffd_install_s": uffd_cost,
            "mmap_over_uffd": mmap_cost / uffd_cost if uffd_cost else 0.0,
        })
    lens = np.asarray(all_lens)
    cdf_points = {str(k): float((lens <= k).mean()) for k in (1, 2, 3, 4, 8, 16, 64, 256)}
    out = {
        "rows": rows,
        "aggregate": {
            "mean_run": float(lens.mean()),
            "frac_runs_lt4": float((lens < 4).mean()),
            "mean_runs_per_snapshot": float(np.mean([r["n_runs"] for r in rows])),
            "cdf": cdf_points,
        },
        "paper": {"mean_run": 5.0, "frac_runs_lt4": 0.90,
                  "mean_runs_per_snapshot": 4164.2, "mmap_over_uffd": 2.6},
    }
    OUT.mkdir(exist_ok=True)
    (OUT / "runlength.json").write_text(json.dumps(out, indent=2))
    return out


def main():
    out = run()
    for r in out["rows"]:
        print(f"{r['workload']:14s} hot={r['n_hot_pages']:6d} runs={r['n_runs']:5d} "
              f"mean={r['mean_run']:5.1f} lt4={r['frac_runs_lt4']:4.0%} "
              f"mmap/uffd={r['mmap_over_uffd']:.1f}x")
    a = out["aggregate"]
    print(f"AGGREGATE mean_run={a['mean_run']:.1f} lt4={a['frac_runs_lt4']:.0%} "
          f"runs/snapshot={a['mean_runs_per_snapshot']:.0f}  CDF={a['cdf']}")
    print(f"PAPER     mean_run=5.0 lt4=90% runs/snapshot=4164 (weights are "
          f"contiguous in our images → longer runs than a Python heap)")


if __name__ == "__main__":
    main()
