"""Restore-path microbenchmark: per-page vs run-coalesced batched serving.

For each workload we publish the snapshot once, then perform two REAL
restores (actual byte movement through the pool emulation) with fresh
incoherent host views:

  per_page : the strictly page-at-a-time path — one HostView read + one
             lock-acquiring uffd.copy per 4 KiB page, one RDMA read per
             cold page.
  batched  : the run-coalesced path — chunked CXL streaming over the
             compact hot region, one uffd ioctl per guest-contiguous run,
             one RDMA read per cold extent.

Both must produce bit-identical images; the batched path must never model
more time than the per-page path and must install exactly the same bytes.
With ``zstandard`` available the same comparison runs against a
zstd-compressed cold tier.  Results land in experiments/serving_bench.json.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core import HierarchicalPool, PoolMaster, StateImage
from repro.core.serving import Instance, RestoreEngine
from repro.core.snapshot import SnapshotReader, _zstd
from repro.core.pool import TimeLedger
from .workloads import all_workloads, get_workload

OUT = Path(__file__).resolve().parents[1] / "experiments"


def _one_restore(pool, regions, image, mode: str) -> dict:
    batched = mode == "batched"
    ledger = TimeLedger()
    view = pool.host_view(f"bench-{mode}", ledger)
    reader = SnapshotReader(regions, view, pool.rdma)
    reader.invalidate_cxl()
    inst = Instance(StateImage.empty_like(image.manifest), ledger)
    eng = RestoreEngine(reader, inst, rdma_engine=None)

    t0 = time.perf_counter()
    eng.pre_install_hot(use_batch=batched)
    pre_s = {k: v for k, v in ledger.seconds.items()}
    eng.install_all_sync(use_batch=batched)
    wall_s = time.perf_counter() - t0

    return {
        "preinstall_modeled_s": pre_s.get("cxl_read", 0.0) + pre_s.get("uffd_copy", 0.0),
        "total_modeled_s": ledger.total(),
        "ledger_s": dict(ledger.seconds),
        "wall_s": wall_s,
        "bit_identical": bool(np.array_equal(inst.image.buf, image.buf)),
        "bytes_installed": inst.stats["bytes_installed"],
        "cxl_bytes_read": view.stats["bytes_read"],
        "uffd_batches": inst.stats["uffd_batches"],
        "uffd_copies": inst.stats["uffd_copies"],
    }


def bench_workload(name: str, compress_cold: bool = False) -> dict:
    bw = get_workload(name)
    pool = HierarchicalPool(cxl_capacity=1 << 30, rdma_capacity=2 << 30)
    master = PoolMaster(pool)
    regions = master.publish(name, bw.image, bw.profile.working_set,
                             compress_cold=compress_cold)
    modes = {m: _one_restore(pool, regions, bw.image, m)
             for m in ("per_page", "batched")}
    pp, bt = modes["per_page"], modes["batched"]
    row = {
        "workload": name,
        "cold_compressed": bool(regions.cold_compressed),
        "modes": modes,
        "preinstall_speedup": pp["preinstall_modeled_s"] / max(bt["preinstall_modeled_s"], 1e-12),
        "total_speedup": pp["total_modeled_s"] / max(bt["total_modeled_s"], 1e-12),
        "bit_identical_both": pp["bit_identical"] and bt["bit_identical"],
        "bytes_match": pp["bytes_installed"] == bt["bytes_installed"],
        "batched_not_slower": bt["total_modeled_s"] <= pp["total_modeled_s"] + 1e-12,
    }
    return row


def run(workloads=None) -> dict:
    names = list(workloads) if workloads else all_workloads()
    rows = [bench_workload(n) for n in names]
    rows_z = [bench_workload(n, compress_cold=True) for n in names] if _zstd else []
    ok = all(r["bit_identical_both"] and r["bytes_match"] and r["batched_not_slower"]
             for r in rows + rows_z)
    out = {
        "rows": rows,
        "rows_compressed_cold": rows_z,
        "zstd_available": _zstd is not None,
        "all_bit_identical_and_not_slower": ok,
    }
    OUT.mkdir(exist_ok=True)
    (OUT / "serving_bench.json").write_text(json.dumps(out, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="chameleon only (CI smoke)")
    ap.add_argument("--workloads", nargs="*", default=None)
    args = ap.parse_args()
    names = ["chameleon"] if args.quick else args.workloads
    out = run(names)
    print(f"{'workload':14s}{'pre pp(ms)':>11s}{'pre bt(ms)':>11s}{'x':>6s}"
          f"{'tot pp(ms)':>11s}{'tot bt(ms)':>11s}{'x':>6s}  ok")
    for r in out["rows"] + out["rows_compressed_cold"]:
        pp, bt = r["modes"]["per_page"], r["modes"]["batched"]
        tag = r["workload"] + ("+z" if r["cold_compressed"] else "")
        print(f"{tag:14s}{pp['preinstall_modeled_s']*1e3:11.2f}"
              f"{bt['preinstall_modeled_s']*1e3:11.2f}{r['preinstall_speedup']:6.2f}"
              f"{pp['total_modeled_s']*1e3:11.2f}{bt['total_modeled_s']*1e3:11.2f}"
              f"{r['total_speedup']:6.2f}  "
              f"{r['bit_identical_both'] and r['bytes_match'] and r['batched_not_slower']}")
    print(f"all bit-identical & batched never slower: {out['all_bit_identical_and_not_slower']}")


if __name__ == "__main__":
    main()
