"""Fleet-serving benchmark (ISSUE 7 acceptance): traffic in, tail out.

Real bytes, modeled time, end to end:

1. a REAL pod is built — every function type's snapshot is published
   through ``PoolMaster`` into the content-addressed dedup store, admission
   priced by ``DedupStore.probe_new_bytes`` (the marginal-byte probe the
   capacity manager admits on) and residency audited with
   ``exclusive_cxl_bytes`` (the store's ground truth for how many of a
   variant's hot bytes are shared with its base group);
2. each snapshot is profiled via a production ``SnapshotReader`` into a
   :class:`~repro.fleet.model.RestoreProfile`; the profile must reproduce
   ``strategies.modeled_concurrent_restore_s`` exactly (asserted here);
3. a sample of variants is restored for real through the serving path
   (borrow → flush → extent walk) and byte-compared against its image;
4. a seeded heavy-tailed trace (Zipf rates; Poisson/diurnal/ON-OFF mix)
   drives the :class:`~repro.fleet.driver.FleetDriver` under each
   placement policy — **locality vs random vs round_robin** A/B on the
   SAME trace — with keep-warm economics and queue-depth autoscaling on.

Reported per policy: p50/p99/mean modeled cold-start, modeled throughput,
warm/join fractions, peak hosts and in-flight concurrency.  Acceptance:
locality beats random by >= 1.3x on p99 modeled cold-start, the full run
covers >= 200 function types at >= 10k peak in-flight invocations, and two
identically-seeded locality runs are bit-identical.

All compared keys are modeled/deterministic (fixed default seed; CI holds
them to ±10%).  Results land in ``experiments/fleet_bench.json`` (full) or
``fleet_bench_quick.json`` (``--quick`` CI smoke).
"""
from __future__ import annotations

import argparse
import json
import math
import os
from pathlib import Path

import numpy as np

from repro.core import (
    HierarchicalPool,
    Instance,
    PoolMaster,
    RestoreEngine,
    SnapshotReader,
    StateImage,
)
from repro.core.pagestore import PAGE_SIZE
from repro.core.snapshot import exclusive_cxl_bytes
from repro.fleet import (
    FleetDriver,
    FleetTopology,
    QueueAutoscaler,
    generate_trace,
    plan_balanced,
    plan_replicated,
    plan_single,
    profile_reader,
    synthesize_fleet,
)
from repro.serve.strategies import modeled_concurrent_restore_s
from repro.sim.clock import VirtualClock

OUT = Path(__file__).resolve().parents[1] / "experiments"
SEED = int(os.environ.get("AQUIFER_SIM_SEED", "0"))


def build_pod(fleet, hot_pages, cold_pages, zero_pages, delta_pages,
              seed=SEED):
    """Publish one dedup variant snapshot per function type: variants of a
    base group share that group's base hot pages and differ in
    ``delta_pages`` private rows plus a private cold arena."""
    rng = np.random.default_rng(seed)
    n_bases = max(f.base_group for f in fleet) + 1
    bases = [rng.integers(1, 255, hot_pages * PAGE_SIZE,
                          dtype=np.int64).astype(np.uint8)
             for _ in range(n_bases)]
    pool = HierarchicalPool(cxl_capacity=1 << 30, rdma_capacity=1 << 30)
    # budget: dedup keeps a base group's shared pages once, so the pod fits
    # comfortably; the margin still makes the capacity manager account
    # every publish through probe_new_bytes-style marginal admission
    budget = (n_bases * hot_pages + len(fleet) * (delta_pages + 4)) * PAGE_SIZE * 2
    master = PoolMaster(pool, cxl_budget=budget, dedup=True)
    images, probes = {}, []
    for f in fleet:
        w = bases[f.base_group].copy()
        lo = (f.fn_id * delta_pages) % hot_pages
        for d in range(delta_pages):
            p = (lo + d) % hot_pages
            w[p * PAGE_SIZE:(p + 1) * PAGE_SIZE] = \
                rng.integers(1, 255, PAGE_SIZE).astype(np.uint8)
        img = StateImage.build({
            "w": w,
            "cold": rng.integers(1, 255, cold_pages * PAGE_SIZE).astype(np.uint8),
            "z": np.zeros(zero_pages * PAGE_SIZE, np.uint8),
        })
        ws = list(range(img.manifest.by_name()["w"].page_count))
        # marginal CXL bytes this publish will newly allocate (admission's
        # ground truth): first variant of a group pays its base, the rest
        # pay ~delta_pages
        probes.append(int(pool.dedup_cxl.probe_new_bytes(
            img.pages_matrix()[ws])))
        master.publish(f.name, img, ws)
        images[f.fn_id] = img
    return pool, master, images, probes


def profile_pod(pool, master, fleet):
    """One RestoreProfile per published snapshot, with its shared-base
    fraction taken from the dedup store's refcounts (exclusive_cxl_bytes),
    and an exactness check against the analytic restore model."""
    profiles = {}
    max_err = 0.0
    for f in fleet:
        entry = master.catalog.find(f.name)
        assert entry is not None and entry.regions is not None, \
            f"{f.name} not resident"
        r = entry.regions
        reader = SnapshotReader(r, pool.host_view(f"prof-{f.name}"), pool.rdma)
        hot_bytes = r.n_hot * PAGE_SIZE
        excl = exclusive_cxl_bytes(pool, r)
        prof = profile_reader(reader,
                              shared_base_bytes=max(0, hot_bytes - excl),
                              exclusive_bytes=excl)
        for conc in (1, 4):
            want = modeled_concurrent_restore_s(reader, conc)
            got = prof.cold_start_s(conc)
            max_err = max(max_err, abs(want - got))
            assert math.isclose(want, got, rel_tol=1e-12), \
                f"profile departs from restore model: {want} vs {got}"
        profiles[f.fn_id] = prof
    return profiles, max_err


def verify_restores(pool, master, images, fleet, n_sample):
    """Production-path restore + byte-compare for a deterministic sample."""
    idx = np.linspace(0, len(fleet) - 1, n_sample).astype(int)
    ok = []
    for i in idx:
        f = fleet[int(i)]
        borrow = master.catalog.borrow(f.name)
        assert borrow is not None
        try:
            reader = SnapshotReader(borrow.regions,
                                    pool.host_view(f"v-{f.name}"), pool.rdma)
            reader.invalidate_cxl()
            inst = Instance(StateImage.empty_like(images[f.fn_id].manifest))
            RestoreEngine(reader, inst, rdma_engine=None).install_all_sync()
            ok.append(bool(inst.all_present() and
                           np.array_equal(inst.image.buf,
                                          images[f.fn_id].buf)))
        finally:
            borrow.release()
    return bool(all(ok)), len(ok)


def drive(fleet, profiles, trace, policy, n_hosts, slots, max_hosts):
    d = FleetDriver(fleet, profiles, policy=policy, seed=SEED,
                    n_hosts=n_hosts, slots_per_host=slots,
                    clock=VirtualClock(),
                    autoscaler=QueueAutoscaler(min_hosts=n_hosts,
                                               max_hosts=max_hosts))
    return d.run(trace)


def run(quick: bool = False) -> dict:
    if quick:
        n_types, n_bases = 24, 6
        hot, cold, zero, delta = 48, 24, 16, 4
        total_rps, t_end, compute_mean = 500.0, 8.0, 0.25
        n_hosts, slots, max_hosts = 6, 16, 32
        n_sample = 4
        target_hot = 64 << 20
    else:
        n_types, n_bases = 200, 16
        hot, cold, zero, delta = 64, 32, 16, 6
        total_rps, t_end, compute_mean = 4000.0, 45.0, 2.0
        n_hosts, slots, max_hosts = 48, 64, 192
        n_sample = 8
        target_hot = 256 << 20

    fleet = synthesize_fleet(n_types, n_bases, total_rps, seed=SEED,
                             compute_mean_s=compute_mean)
    pool, master, images, probes = build_pod(fleet, hot, cold, zero, delta)
    profiles, model_err = profile_pod(pool, master, fleet)
    bit_identical, n_verified = verify_restores(pool, master, images, fleet,
                                                n_sample)
    # extrapolate the (exactness-checked) profiles to production-size
    # images — same layout shape, target_hot hot bytes — so the driver's
    # keep-warm economics and contention run at realistic magnitudes
    scale = target_hot / (hot * PAGE_SIZE)
    profiles = {k: p.scaled(scale) for k, p in profiles.items()}
    trace = generate_trace(fleet, t_end, seed=SEED)

    results = {p: drive(fleet, profiles, trace, p, n_hosts, slots, max_hosts)
               for p in ("locality", "random", "round_robin")}
    policies = {p: r.summary() for p, r in results.items()}
    # bit-determinism: an identically-seeded locality re-run must match
    r1 = results["locality"]
    r2 = drive(fleet, profiles, trace, "locality", n_hosts, slots, max_hosts)
    deterministic = bool(
        np.array_equal(r1.host, r2.host)
        and np.array_equal(r1.mode, r2.mode)
        and np.array_equal(r1.ready_s, r2.ready_s, equal_nan=True)
        and np.array_equal(r1.done_s, r2.done_s, equal_nan=True))

    loc, rnd = policies["locality"], policies["random"]
    p99_x = (rnd["p99_cold_start_s"] / loc["p99_cold_start_s"]
             if loc["p99_cold_start_s"] > 0 else float("inf"))
    shared_frac = float(np.mean(
        [profiles[f.fn_id].shared_base_bytes
         / max(1, profiles[f.fn_id].hot_bytes) for f in fleet]))
    criteria = {
        "locality_vs_random_p99_ge_1_3x": bool(p99_x >= 1.3),
        "bit_deterministic": deterministic,
        "restores_bit_identical": bit_identical,
        "profile_matches_restore_model": bool(model_err == 0.0),
        "all_completed": bool(all(p["completed"] == p["invocations"]
                                  for p in policies.values())),
    }
    if not quick:
        criteria["ge_200_function_types"] = bool(n_types >= 200)
        criteria["ge_10k_peak_inflight"] = bool(
            loc["inflight_peak"] >= 10_000)
    out = {
        "quick": quick, "seed": SEED,
        "fleet": {"n_types": n_types, "n_bases": n_bases,
                  "hot_pages": hot, "cold_pages": cold, "zero_pages": zero,
                  "delta_pages": delta, "total_rps": total_rps,
                  "t_end_s": t_end, "invocations": len(trace),
                  "n_hosts": n_hosts, "slots_per_host": slots,
                  "max_hosts": max_hosts},
        "pod": {"profile_scale_x": scale,
                "probe_marginal_bytes_total": int(sum(probes)),
                "probe_marginal_bytes_first": int(probes[0]),
                "probe_marginal_bytes_last": int(probes[-1]),
                "mean_shared_base_frac": shared_frac,
                "restores_verified": n_verified,
                "capacity": master.capacity.report()},
        "policies": policies,
        "locality_vs_random_p99_x": p99_x,
        "criteria": criteria,
    }
    OUT.mkdir(exist_ok=True)
    name = "fleet_bench_quick.json" if quick else "fleet_bench.json"
    (OUT / name).write_text(json.dumps(out, indent=2))
    return out


def drive_topo(fleet, profiles, trace, topo, n_hosts, slots):
    """One locality-policy run with a FleetTopology surcharging the
    scheduler's scores and the driver's restore charges.  No autoscaler:
    the tier comparison is same-hardware, same-budget — only the replica
    plan differs."""
    d = FleetDriver(fleet, profiles, policy="locality", seed=SEED,
                    n_hosts=n_hosts, slots_per_host=slots,
                    clock=VirtualClock())
    d.scheduler.topology = topo
    return d.run(trace)


def run_multipod(quick: bool = False) -> dict:
    """Multi-pod tier (ISSUE 9): replication + migration economics vs the
    single-big-pod and no-replication baselines at equal TOTAL CXL budget.

    Pods are Octopus-shaped: ``device_ports`` head ports per MHD, so the
    single big pod CXL-attaches only ``device_ports`` hosts while k pods
    attach k× as many — but must split the budget and (without
    replication) scatter each snapshot into exactly one pod.  The
    replicated tier spends the same budget's headroom on second replicas,
    gated by ``migration_economics`` priced on MEASURED demand: the
    per-pod cold-restore counts of the no-replication run (migration
    toward demand, not toward raw invocation rates — warm-served hot
    functions don't re-read their hot set)."""
    if quick:
        n_types, n_bases = 24, 6
        hot, cold, zero, delta = 48, 24, 16, 4
        total_rps, t_end, compute_mean = 500.0, 8.0, 0.25
        n_hosts, slots = 6, 64
        n_pods, device_ports = 3, 2
        target_hot = 64 << 20
    else:
        n_types, n_bases = 200, 16
        hot, cold, zero, delta = 64, 32, 16, 6
        total_rps, t_end, compute_mean = 2000.0, 45.0, 1.0
        n_hosts, slots = 48, 96
        n_pods, device_ports = 4, 12
        target_hot = 256 << 20

    fleet = synthesize_fleet(n_types, n_bases, total_rps, seed=SEED,
                             compute_mean_s=compute_mean)
    pool, master, images, _probes = build_pod(fleet, hot, cold, zero, delta)
    profiles, model_err = profile_pod(pool, master, fleet)
    bit_identical, n_verified = verify_restores(pool, master, images, fleet, 4)
    scale = target_hot / (hot * PAGE_SIZE)
    profiles = {k: p.scaled(scale) for k, p in profiles.items()}
    trace = generate_trace(fleet, t_end, seed=SEED)

    # equal TOTAL CXL budget across tiers: 1.5x the fleet's hot bytes —
    # one copy of everything fits with headroom, full k-replication would not
    budget = int(1.5 * sum(p.hot_bytes for p in profiles.values()))

    plans = {"single_pod": (1, plan_single(fleet)),
             "no_replication": (n_pods, plan_balanced(fleet, profiles,
                                                      n_pods)[0])}
    tiers, topos = {}, {}

    def run_tier(tier, k, plan):
        topo = FleetTopology(k, device_ports, plan)
        result = drive_topo(fleet, profiles, trace, topo, n_hosts, slots)
        s = result.summary()
        s["topology"] = dict(topo.stats)
        s["n_pods"] = k
        s["attached_hosts"] = sum(1 for h in range(n_hosts)
                                  if topo.attached(h))
        tiers[tier] = s
        topos[tier] = (topo, result)
        return result

    for tier, (k, plan) in plans.items():
        run_tier(tier, k, plan)

    # measured demand: a second replica serves one pod's share of the cold
    # restores actually paid without it — warm hits and joins never re-read
    # the hot set, so they contribute no replica benefit
    base = topos["no_replication"][1]
    cold_mask = base.mode == 0          # MODE_COLD
    cold_by_fn = np.bincount(base.fn[cold_mask].astype(int),
                             minlength=n_types)
    expected_reads = {f.fn_id: float(cold_by_fn[f.fn_id]) / n_pods
                      for f in fleet}
    rep_plan, rep_stats = plan_replicated(fleet, profiles, n_pods, budget,
                                          expected_reads)
    run_tier("replicated", n_pods, rep_plan)

    # bit-determinism: an identically-seeded replicated re-run must match
    r1 = topos["replicated"][1]
    r2 = drive_topo(fleet, profiles, trace,
                    FleetTopology(n_pods, device_ports, rep_plan),
                    n_hosts, slots)
    deterministic = bool(
        np.array_equal(r1.host, r2.host)
        and np.array_equal(r1.mode, r2.mode)
        and np.array_equal(r1.ready_s, r2.ready_s, equal_nan=True)
        and np.array_equal(r1.done_s, r2.done_s, equal_nan=True))

    rep, single, norep = (tiers["replicated"], tiers["single_pod"],
                          tiers["no_replication"])
    criteria = {
        "replicated_beats_single_pod_p99": bool(
            rep["p99_cold_start_s"] < single["p99_cold_start_s"]),
        "replicated_beats_no_replication_p99": bool(
            rep["p99_cold_start_s"] <= norep["p99_cold_start_s"]),
        "economics_gate_filtered": bool(
            rep_stats["replicas_added"] > 0
            and rep_stats["skipped_uneconomic"] > 0),
        "bit_deterministic": deterministic,
        "restores_bit_identical": bit_identical,
        "profile_matches_restore_model": bool(model_err == 0.0),
        "all_completed": bool(all(t["completed"] == t["invocations"]
                                  for t in tiers.values())),
    }
    out = {
        "quick": quick, "seed": SEED,
        "fleet": {"n_types": n_types, "n_bases": n_bases,
                  "invocations": len(trace), "t_end_s": t_end,
                  "n_hosts": n_hosts, "slots_per_host": slots,
                  "n_pods": n_pods, "device_ports": device_ports,
                  "total_cxl_budget_bytes": budget,
                  "restores_verified": n_verified},
        "replication_plan": rep_stats,
        "tiers": tiers,
        "single_vs_replicated_p99_x": (
            single["p99_cold_start_s"] / rep["p99_cold_start_s"]
            if rep["p99_cold_start_s"] > 0 else float("inf")),
        "criteria": criteria,
    }
    OUT.mkdir(exist_ok=True)
    name = ("fleet_bench_multipod_quick.json" if quick
            else "fleet_bench_multipod.json")
    (OUT / name).write_text(json.dumps(out, indent=2))
    return out


def main_multipod(quick: bool) -> int:
    out = run_multipod(quick=quick)
    f = out["fleet"]
    print(f"multipod: {f['n_types']} types, {f['invocations']} invocations, "
          f"{f['n_pods']} pods x {f['device_ports']} ports, "
          f"budget {f['total_cxl_budget_bytes'] >> 20} MiB total")
    print(f"replication plan: {out['replication_plan']}")
    for tier, s in out["tiers"].items():
        topo = s["topology"]
        print(f"{tier:>16}: p50 {s['p50_cold_start_s']*1e3:8.3f} ms  "
              f"p99 {s['p99_cold_start_s']*1e3:8.3f} ms  "
              f"local/remote/unattached {topo['local_placements']}/"
              f"{topo['remote_placements']}/{topo['unattached_placements']}")
    print(f"single_pod vs replicated p99: "
          f"{out['single_vs_replicated_p99_x']:.2f}x")
    ok = all(out["criteria"].values())
    print(f"criteria: {out['criteria']}  ->  {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI smoke (small fleet)")
    ap.add_argument("--multipod", action="store_true",
                    help="multi-pod replication/migration tier")
    args = ap.parse_args()
    if args.multipod:
        raise SystemExit(main_multipod(args.quick))
    out = run(quick=args.quick)
    f = out["fleet"]
    print(f"fleet: {f['n_types']} types / {f['n_bases']} bases, "
          f"{f['invocations']} invocations over {f['t_end_s']}s "
          f"({f['total_rps']:.0f} rps offered)")
    print(f"pod: shared-base frac {out['pod']['mean_shared_base_frac']:.3f}, "
          f"probe marginal first/last "
          f"{out['pod']['probe_marginal_bytes_first'] >> 10}/"
          f"{out['pod']['probe_marginal_bytes_last'] >> 10} KiB, "
          f"{out['pod']['restores_verified']} real restores verified")
    for name, p in out["policies"].items():
        print(f"{name:>12}: p50 {p['p50_cold_start_s']*1e3:8.3f} ms  "
              f"p99 {p['p99_cold_start_s']*1e3:8.3f} ms  "
              f"warm {p['warm_frac']:.3f}  join {p['join_frac']:.3f}  "
              f"hosts {p['host_peak']}  inflight {p['inflight_peak']}")
    print(f"locality vs random p99: {out['locality_vs_random_p99_x']:.2f}x")
    ok = all(out["criteria"].values())
    print(f"criteria: {out['criteria']}  ->  {'PASS' if ok else 'FAIL'}")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
