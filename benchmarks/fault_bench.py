"""Fault-tolerance benchmark (ISSUE 8 acceptance).

Four sweeps restore the same fused-published snapshot through the
production serving path (``RestoreEngine.install_all_sync`` with a
checksum-verifying ``FusedScatter``), each under a different deterministic
fault schedule on the REAL tiers:

* **none** — the fault-free baseline, run twice: once with no injector and
  once with an armed-but-EMPTY ``FaultInjector`` (plus the attached
  ``TierHealth`` breakers).  The two per-restore cost ledgers must be
  byte-identical — the headline *fault-free overhead of the fault seam is
  exactly 0 modeled seconds*;
* **rdma_timeouts** — two injected RNIC read timeouts per restore; the
  engine's seeded retry/backoff machinery re-issues and every restore
  still ends bit-identical, with the wasted wire time and backoff charged
  to modeled time;
* **cxl_poison** — one injected per-page poison per restore on a hot
  page's home offset; the checksum mismatch is detected at install time
  and repaired from the (clean) home tier within the repair budget;
* **brownout** — a CXL host-link brownout covering the whole run; the
  breaker opens and every restore completes DEGRADED over the RDMA-only
  path (never fails), at the modeled all-cold cost
  (``strategies.modeled_degraded_restore_s``).

All reported keys are modeled/deterministic under ``VirtualClock`` (fixed
default seed; CI's regression gate holds them to ±10%, booleans exactly).
Results land in ``experiments/fault_bench.json`` (full) or
``fault_bench_quick.json`` (``--quick`` CI smoke).
"""
from __future__ import annotations

import argparse
import json
import os
from pathlib import Path

import numpy as np

from repro.core import (
    FaultInjector,
    HierarchicalPool,
    Instance,
    PoolMaster,
    RestoreEngine,
    SnapshotReader,
    StateImage,
    TimeLedger,
)
from repro.core.pagestore import PAGE_SIZE
from repro.kernels.snapshot_fuse import FusedScatter, make_fused_publish_fn
from repro.serve.strategies import (
    modeled_concurrent_restore_s,
    modeled_degraded_restore_s,
)
from repro.sim import VirtualClock

OUT = Path(__file__).resolve().parents[1] / "experiments"
SEED = int(os.environ.get("AQUIFER_SIM_SEED", "0"))

SWEEPS = ("none", "rdma_timeouts", "cxl_poison", "brownout")


def make_image(hot_pages: int, cold_pages: int, zero_pages: int,
               seed: int = SEED):
    rng = np.random.default_rng(seed + 7)
    img = StateImage.build({
        "w": rng.integers(1, 255, hot_pages * PAGE_SIZE).astype(np.uint8),
        "cold": rng.integers(1, 255, cold_pages * PAGE_SIZE).astype(np.uint8),
        "z": np.zeros(zero_pages * PAGE_SIZE, np.uint8),
    })
    return img, list(range(hot_pages))


def make_stack(img, ws):
    """Fresh pool + fused publish (so restores carry a checksum table)."""
    pool = HierarchicalPool(cxl_capacity=1 << 30, rdma_capacity=1 << 30)
    master = PoolMaster(pool)
    master.publish("snap", img, ws,
                   publish_fn=make_fused_publish_fn(use_pallas=False))
    borrow = master.catalog.borrow("snap")
    assert borrow is not None
    return pool, master, borrow


def injector_for(sweep: str, r: int, pool, borrow, clock) -> FaultInjector:
    """The per-restore fault schedule.  A FRESH injector per restore keeps
    the counts exact (2 timeouts / 1 poison each) regardless of how a
    previous restore's repairs consumed its windows."""
    inj = FaultInjector(clock=clock, seed=SEED + r)
    if sweep == "rdma_timeouts":
        inj.fail_reads("rdma", 2)
    elif sweep == "cxl_poison":
        probe = SnapshotReader(borrow.regions,
                               pool.host_view(f"probe{r}"), pool.rdma)
        _kind, off = probe.lookup(int(probe.hot_page_indices()[0]))
        inj.poison_reads("cxl", 1, lo=off, hi=off + PAGE_SIZE)
    elif sweep == "brownout":
        inj.brownout("cxl", start_s=0.0, duration_s=1e9)
    return inj


def run_sweep(sweep: str, n_restores: int, img, ws, armed: bool = True):
    """``n_restores`` sequential production restores under one schedule
    kind; returns per-restore modeled seconds + fault/repair accounting."""
    clock = VirtualClock()
    pool, _master, borrow = make_stack(img, ws)
    restore_s, ledgers = [], []
    ok = True
    totals = {"retries": 0, "repairs": 0, "degraded": 0, "injected": 0}
    for r in range(n_restores):
        if armed:
            pool.attach_fault_injector(injector_for(sweep, r, pool, borrow,
                                                    clock))
        led = TimeLedger()
        view = pool.host_view(f"h{r}", led)
        reader = SnapshotReader(borrow.regions, view, pool.rdma)
        reader.invalidate_cxl()
        inst = Instance(StateImage.empty_like(img.manifest), ledger=led,
                        clock=clock)
        eng = RestoreEngine(reader, inst, None, retry_seed=r,
                            scatter_fn=FusedScatter(use_pallas=False),
                            clock=clock)
        eng.install_all_sync(use_batch=True)
        ok = ok and bool(inst.all_present()
                         and np.array_equal(inst.image.buf, img.buf))
        restore_s.append(float(led.total()))
        ledgers.append(dict(led.seconds))
        totals["retries"] += len(eng.retry_trace)
        totals["repairs"] += eng.repair_stats["checksum_repairs"]
        totals["degraded"] += int(eng.degraded_cxl)
        if armed:
            fi = pool.fault_injector
            totals["injected"] += (fi.stats["injected_timeouts"]
                                   + fi.stats["injected_poison"]
                                   + fi.stats["brownout_rejections"])
    arr = np.asarray(restore_s)
    bytes_per_restore = img.buf.nbytes
    return {
        "n_restores": n_restores,
        "p50_modeled_ms": float(np.percentile(arr, 50) * 1e3),
        "p99_modeled_ms": float(np.percentile(arr, 99) * 1e3),
        "total_modeled_s": float(arr.sum()),
        "goodput_GBps": float(n_restores * bytes_per_restore
                              / max(arr.sum(), 1e-12) / 1e9),
        "total_retries": totals["retries"],
        "total_repairs": totals["repairs"],
        "n_degraded": totals["degraded"],
        "total_injected": totals["injected"],
        "all_bit_identical": ok,
        "_ledgers": ledgers,
    }


def degraded_model_ms(img, ws) -> dict:
    """The analytic healthy vs degraded restore models over this layout."""
    pool, _master, borrow = make_stack(img, ws)
    reader = SnapshotReader(borrow.regions, pool.host_view("model"),
                            pool.rdma)
    return {
        "healthy_ms": float(modeled_concurrent_restore_s(reader, 1) * 1e3),
        "degraded_ms": float(modeled_degraded_restore_s(reader, 1) * 1e3),
    }


def run(quick: bool = False) -> dict:
    if quick:
        n_restores, hot, cold, zero = 8, 64, 64, 32
    else:
        n_restores, hot, cold, zero = 32, 256, 256, 128
    img, ws = make_image(hot, cold, zero)

    # fault-free overhead: bare stack vs armed-but-empty injector
    bare = run_sweep("none", n_restores, img, ws, armed=False)
    sweeps = {s: run_sweep(s, n_restores, img, ws) for s in SWEEPS}
    fault_free_identical = sweeps["none"]["_ledgers"] == bare["_ledgers"]
    overhead_pct = (
        0.0 if fault_free_identical
        else abs(sweeps["none"]["total_modeled_s"] - bare["total_modeled_s"])
        / max(bare["total_modeled_s"], 1e-12) * 100.0)
    model = degraded_model_ms(img, ws)

    criteria = {
        "fault_free_overhead_zero": bool(fault_free_identical),
        "all_bit_identical": bool(all(sweeps[s]["all_bit_identical"]
                                      for s in SWEEPS)),
        "retries_recovered": bool(sweeps["rdma_timeouts"]["total_retries"] > 0
                                  and sweeps["rdma_timeouts"]
                                  ["all_bit_identical"]),
        "repairs_happened": bool(sweeps["cxl_poison"]["total_repairs"]
                                 == n_restores),
        "brownout_degrades_not_fails": bool(
            sweeps["brownout"]["n_degraded"] == n_restores
            and sweeps["brownout"]["all_bit_identical"]),
        "degraded_costs_more": bool(
            sweeps["brownout"]["p50_modeled_ms"]
            > sweeps["none"]["p50_modeled_ms"]
            and model["degraded_ms"] > model["healthy_ms"]),
        # the degraded path's EXECUTED ledger must track the analytic
        # all-cold model (ISSUE 8: "modeled time matching the strategies
        # module's all-cold cost")
        "degraded_model_within_15pct": bool(
            abs(sweeps["brownout"]["p50_modeled_ms"] - model["degraded_ms"])
            <= 0.15 * model["degraded_ms"]),
    }
    for s in sweeps.values():
        s.pop("_ledgers")
    bare.pop("_ledgers")
    out = {
        "quick": quick, "seed": SEED,
        "workload": {"n_restores": n_restores, "hot_pages": hot,
                     "cold_pages": cold, "zero_pages": zero},
        "fault_free_overhead_pct": overhead_pct,
        "sweeps": sweeps,
        "degraded_model": model,
        "criteria": criteria,
    }
    OUT.mkdir(exist_ok=True)
    name = "fault_bench_quick.json" if quick else "fault_bench.json"
    (OUT / name).write_text(json.dumps(out, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke (small snapshot, fewer restores)")
    args = ap.parse_args()
    out = run(quick=args.quick)
    w = out["workload"]
    print(f"workload: {w['n_restores']} restores x "
          f"({w['hot_pages']} hot + {w['cold_pages']} cold + "
          f"{w['zero_pages']} zero) pages, seed {out['seed']}")
    print(f"fault-free overhead of the armed seam: "
          f"{out['fault_free_overhead_pct']:.3f}%")
    for s in SWEEPS:
        r = out["sweeps"][s]
        print(f"  {s:14s} p50 {r['p50_modeled_ms']:8.3f} ms  "
              f"p99 {r['p99_modeled_ms']:8.3f} ms  "
              f"retries {r['total_retries']:3d}  repairs "
              f"{r['total_repairs']:3d}  degraded {r['n_degraded']:3d}  "
              f"{'bit-identical' if r['all_bit_identical'] else 'CORRUPT'}")
    m = out["degraded_model"]
    print(f"analytic restore model: healthy {m['healthy_ms']:.3f} ms vs "
          f"degraded (RDMA-only) {m['degraded_ms']:.3f} ms")
    ok = all(out["criteria"].values())
    print(f"criteria: {out['criteria']}  ->  {'PASS' if ok else 'FAIL'}")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
