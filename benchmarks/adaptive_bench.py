"""Online hotness-feedback benchmark (ISSUE 4 acceptance).

The paper's hotness classification is offline: the hot set is frozen into
the snapshot at publish time.  This benchmark drifts the invocation working
set mid-run and compares invocation latency under

  frozen    : the v0 snapshot keeps serving — every drifted page takes the
              demand-fault path (trap + urgent RDMA read + uffd.copy) on
              every fresh restore, forever;
  adaptive  : the restores' demand-fault/prefetch-hit/touch telemetry feeds
              the per-(name, version) HeatMap; once the modeled benefit
              clears the rebuild break-even (strategies.recuration_economics)
              the PoolMaster re-curates — promoting the hot-faulting drift
              pages into the CXL region and demoting the never-touched
              "hot" pages to RDMA — and republishes through the ownership
              protocol; post-re-curation restores pre-install the drifted
              set.

All restores perform REAL byte movement and are verified bit-identical to
the published image (including across the re-curation republish).  Times
are modeled seconds (DESIGN.md §2): ledger deltas during the invocation
plus the userfaultfd trap cost per major fault.

A second section exercises the CXL capacity manager: snapshots published
into a pod whose CXL budget fits only a fraction of them must degrade
(clock-demote LRU victims to RDMA / spill the newcomer's hot set) instead
of failing alloc — every one of them must still restore bit-identically.

Acceptance (checked into the emitted json): after the drift, re-curated
restores recover >= 1.3x first-invocation latency vs the frozen hot set,
every restore bit-identical.

Results land in experiments/adaptive_bench.json (full) or
experiments/adaptive_bench_quick.json (--quick CI smoke).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from collections import deque

from repro.core import (
    AccessRecorder,
    HeatRegistry,
    HierarchicalPool,
    LayoutOrderPolicy,
    Orchestrator,
    PoolMaster,
    PredictedOrderPolicy,
    StateImage,
    fit_prefetch_model,
)
from repro.core.pagestore import PAGE_SIZE
from repro.serve.strategies import FAULT_TRAP_S, residual_stall_s

OUT = Path(__file__).resolve().parents[1] / "experiments"


def make_drift_image(seed: int = 0, scale: int = 1):
    """Image with an over-approximated offline hot set and a driftable mass:

      params_used    pages the invocations actually touch (stays hot)
      params_unused  profiled hot but never invoked (demotion candidate)
      table          invocations touch region A, then drift to region B
      arena          zero pages
    """
    rng = np.random.default_rng(seed)
    n_used, n_unused, n_table, n_zero = (96 * scale, 64 * scale,
                                         512 * scale, 192 * scale)
    img = StateImage.build({
        "params_used": rng.standard_normal(n_used * PAGE_SIZE // 4).astype(np.float32),
        "params_unused": rng.standard_normal(n_unused * PAGE_SIZE // 4).astype(np.float32),
        "table": rng.integers(1, 255, (n_table * PAGE_SIZE,)).astype(np.uint8),
        "arena": np.zeros(n_zero * PAGE_SIZE, np.uint8),
    })
    by = img.manifest.by_name()
    t0 = by["table"].first_page
    n_region = n_table // 4
    region_a = np.arange(t0, t0 + n_region)
    region_b = np.arange(t0 + 2 * n_region, t0 + 3 * n_region)
    used = np.asarray(list(by["params_used"].pages()), dtype=np.int64)
    unused = np.asarray(list(by["params_unused"].pages()), dtype=np.int64)
    # offline profile: params (both) + region A — B is cold in v0
    rec = AccessRecorder(img.manifest)
    rec.touch_array("params_used")
    rec.touch_array("params_unused")
    rec.touch_pages(region_a)
    return img, rec.working_set(), {
        "invoke_hot": used, "unused": unused,
        "region_a": region_a, "region_b": region_b,
    }


def run_restore_invocations(orch, name, image, touch_set, n_invocations=3):
    """One full restore lifecycle: warm-restore, replay invocations over
    ``touch_set``, then force-complete + bit-verify.  Per-invocation modeled
    latency = ledger delta + trap cost per major fault taken."""
    ri = orch.restore(name)
    assert ri is not None, "warm restore failed"
    setup_s = ri.ledger.total()
    inv_lat = []
    for _ in range(n_invocations):
        led0 = ri.ledger.total()
        flt0 = ri.instance.stats["fault_rdma"]
        ri.engine.touch_pages(touch_set)
        n_flt = ri.instance.stats["fault_rdma"] - flt0
        inv_lat.append(ri.ledger.total() - led0 + n_flt * FAULT_TRAP_S)
    ri.engine.install_all_sync()
    bit_identical = bool(np.array_equal(ri.instance.image.buf, image.buf))
    version = ri.borrow.version
    stats = dict(ri.instance.stats)
    ri.shutdown()
    return {
        "version": version,
        "setup_modeled_s": setup_s,
        "invocation_s": inv_lat,
        "first_invocation_s": inv_lat[0],
        "fault_rdma": stats["fault_rdma"],
        "bit_identical": bit_identical,
    }


def run_adaptive(quick: bool = False, restores_per_phase: int = 3) -> dict:
    scale = 1 if quick else 2
    img, ws0, sets = make_drift_image(scale=scale)
    pool = HierarchicalPool(cxl_capacity=512 << 20, rdma_capacity=1 << 30)
    heat = HeatRegistry(clock=pool.clock, half_life_s=1e6)
    master = PoolMaster(pool, heat=heat)
    regions0 = master.publish("drifty", img, ws0)
    orch = Orchestrator("bench-host", pool, master.catalog, heat=heat)

    invoke = {
        "warm": np.concatenate([sets["invoke_hot"], sets["region_a"]]),
        "drift": np.concatenate([sets["invoke_hot"], sets["region_b"]]),
    }
    phases = {"warm": [], "frozen": [], "adaptive": []}
    # phase 1: working set matches the profile — the frozen hot set is right
    for _ in range(restores_per_phase):
        phases["warm"].append(
            run_restore_invocations(orch, "drifty", img, invoke["warm"]))
    # phase 2: DRIFT — same snapshot, invocations moved to region B; these
    # restores both measure the frozen penalty and feed the heat map
    for _ in range(restores_per_phase):
        phases["frozen"].append(
            run_restore_invocations(orch, "drifty", img, invoke["drift"]))

    # closed loop: re-curate when the modeled benefit clears the break-even
    hm = heat.find("drifty", regions0.version)
    regions1 = master.recurate("drifty", expected_restores=64)
    assert regions1 is not None, "re-curation should clear the break-even"

    # phase 3: fresh restores serve the re-curated snapshot
    for _ in range(restores_per_phase):
        phases["adaptive"].append(
            run_restore_invocations(orch, "drifty", img, invoke["drift"]))
    orch.close()

    def mean(phase, key):
        return float(np.mean([r[key] for r in phases[phase]]))

    frozen_first = mean("frozen", "first_invocation_s")
    adaptive_first = mean("adaptive", "first_invocation_s")
    # the restore-to-first-response comparison: re-curation moves the drift
    # pages from the per-restore demand-fault path into the (cheaper, CXL)
    # pre-install, so setup grows a little while the first invocation
    # collapses — the ratio of the SUMS is the honest recovery number
    frozen_e2e = mean("frozen", "setup_modeled_s") + frozen_first
    adaptive_e2e = mean("adaptive", "setup_modeled_s") + adaptive_first
    recovery_x = frozen_e2e / max(adaptive_e2e, 1e-12)
    all_bit_identical = all(r["bit_identical"]
                            for rs in phases.values() for r in rs)
    from repro.serve.strategies import recuration_economics
    from repro.core.snapshot import plan_recuration
    return {
        "snapshot": {
            "v0": {"n_hot": regions0.n_hot, "n_cold": regions0.n_cold,
                   "n_zero": regions0.n_zero},
            "recurated": {"version": regions1.version, "n_hot": regions1.n_hot,
                          "n_cold": regions1.n_cold},
            "drift_pages": int(sets["region_b"].size),
            "unused_hot_pages": int(sets["unused"].size),
        },
        "heat": dict(hm.stats),
        "phases": phases,
        "frozen_first_invocation_s": frozen_first,
        "adaptive_first_invocation_s": adaptive_first,
        "frozen_e2e_s": frozen_e2e,
        "adaptive_e2e_s": adaptive_e2e,
        "recovery_x": recovery_x,
        "all_bit_identical": all_bit_identical,
    }


# -- predictive prefetch A/B (ISSUE 10): phase-shifting first-touch order ----

def make_shift_image(seed: int = 0, quick: bool = False):
    """Image whose cold ``table`` splits into equal regions that the
    workload visits in a PERMUTED order — snapshot layout order is
    maximally wrong about what the guest touches next."""
    rng = np.random.default_rng(seed)
    n_regions = 6
    region_pages = 24 if quick else 48
    n_table = n_regions * region_pages
    img = StateImage.build({
        "params": rng.standard_normal(32 * PAGE_SIZE // 4).astype(np.float32),
        "table": rng.integers(1, 255, (n_table * PAGE_SIZE,)).astype(np.uint8),
        "arena": np.zeros(64 * PAGE_SIZE, np.uint8),
    })
    rec = AccessRecorder(img.manifest)
    rec.touch_array("params")               # hot set = params only
    t0 = img.manifest.by_name()["table"].first_page
    perm = rng.permutation(n_regions)
    visit = np.concatenate([
        np.arange(t0 + r * region_pages, t0 + (r + 1) * region_pages)
        for r in perm])
    return img, rec.working_set(), visit, perm.tolist()


def paced_drain_restore(orch, name, image, visit, policy,
                        budget_pages: int = 16) -> dict:
    """Deterministic, thread-free prefetch-vs-touch interleaving at EQUAL
    prefetch bandwidth for every policy: each step installs the next
    ``budget_pages`` pages from the policy-ordered cold-extent queue (real
    RDMA reads), then the guest touches the next ``budget_pages`` pages of
    the visit sequence.  A touched page that has not landed is a residual
    demand fault — charged the full demand stall and served synchronously —
    and, for a reseeding policy, re-orders the remaining queue from the
    faulting page exactly like the NodePageServer pump."""
    ri = orch.restore(name, pre_install=True, prefetch_cold=False)
    assert ri is not None, "warm restore failed"
    eng = ri.engine
    q = deque(policy.order_extents(eng, None))
    n_demand = 0
    prefetched_pages = 0
    i = 0
    while i < len(visit):
        budget = budget_pages
        while budget > 0 and q:
            es, en, rank0, pool_off, nbytes = q.popleft()
            if eng.instance.present[es:es + en].all():
                continue
            payload = eng.reader.rdma.read(pool_off, nbytes)
            eng.ledger.add("rdma_prefetch",
                           eng._rdma_arbiter.charge(nbytes))
            eng._install_verified(np.arange(es, es + en),
                                  eng.reader.split_cold_extent(
                                      rank0, en, payload))
            prefetched_pages += en
            budget -= en
        chunk = visit[i:i + budget_pages]
        i += budget_pages
        for p in chunk:
            p = int(p)
            if eng.instance.present[p]:
                continue
            n_demand += 1            # residual stall: prefetch was elsewhere
            kind, off = eng.reader.lookup(p)
            nbytes = (eng.reader.cold_extent(off)[1]
                      if kind == "rdma_z" else PAGE_SIZE)
            eng.ledger.add("rdma_read", eng._rdma_arbiter.charge(nbytes))
            eng.instance.uffd_copy(p, eng.reader.read_page(p))
            if policy.reseed_on_demand and q:
                rank = {e[0]: j for j, e in enumerate(
                    policy.order_extents(eng, faulting_page=p))}
                q = deque(sorted(q, key=lambda e: rank.get(e[0], len(rank))))
    eng.install_all_sync()
    bit_identical = bool(np.array_equal(ri.instance.image.buf, image.buf))
    ri.shutdown()
    return {
        "demand_faults": n_demand,
        "prefetched_pages": prefetched_pages,
        "residual_stall_s": residual_stall_s(n_demand),
        "bit_identical": bit_identical,
    }


def run_prefetch_ab_point(seed: int, quick: bool,
                          n_training: int = 2) -> dict:
    """One phase-shift point: train the first-touch model from ``n_training``
    instrumented restores, then A/B LayoutOrderPolicy vs PredictedOrderPolicy
    at identical prefetch bandwidth over the same visit sequence."""
    img, ws, visit, perm = make_shift_image(seed=seed, quick=quick)
    pool = HierarchicalPool(cxl_capacity=512 << 20, rdma_capacity=1 << 30)
    heat = HeatRegistry(clock=pool.clock, half_life_s=1e6)
    master = PoolMaster(pool, heat=heat)
    regions = master.publish("shift", img, ws)

    # training: synchronous demand-path restores replay the workload and
    # feed ordered TouchEvents (the engine streams them per session)
    train = Orchestrator("train-host", pool, master.catalog, heat=heat,
                         use_node_server=False, use_async_rdma=False)
    for _ in range(n_training):
        ri = train.restore("shift", pre_install=True, prefetch_cold=False)
        assert ri is not None
        for j in range(0, len(visit), 16):
            ri.engine.touch_pages(visit[j:j + 16])
        ri.engine.install_all_sync()
        assert np.array_equal(ri.instance.image.buf, img.buf)
        ri.shutdown()

    hm = heat.find("shift", regions.version)
    # long horizon + gentle discount: rank the WHOLE phase chain, not just
    # the first few runs (the pump reseeds mid-flight either way)
    model = fit_prefetch_model(hm, discount=0.9, horizon=int(hm.n_runs))
    assert model is not None, "training restores produced no sequences"

    # measurement: heat-free orchestrator (the A run must not teach the B
    # run), same bandwidth + visit sequence for both policies
    bench = Orchestrator("ab-host", pool, master.catalog,
                         use_node_server=False, use_async_rdma=False)
    layout = paced_drain_restore(
        bench, "shift", img, visit, LayoutOrderPolicy(8))
    predicted = paced_drain_restore(
        bench, "shift", img, visit, PredictedOrderPolicy(8, model=model))
    # a policy that predicts perfectly leaves 0 residual faults; floor the
    # denominator at one fault so the ratio stays finite / json-clean
    reduction = (layout["residual_stall_s"]
                 / max(predicted["residual_stall_s"], residual_stall_s(1)))
    return {
        "seed": seed,
        "region_visit_order": perm,
        "visit_pages": int(len(visit)),
        "layout": layout,
        "predicted": predicted,
        "layout_stall_s": layout["residual_stall_s"],
        "predicted_stall_s": predicted["residual_stall_s"],
        "stall_reduction_x": float(reduction),
        "bit_identical": bool(layout["bit_identical"]
                              and predicted["bit_identical"]),
    }


def run_prefetch_ab(quick: bool = False) -> dict:
    """--quick: one seed (the CI-gated point).  Full: sweep several phase
    permutations; the acceptance number is the WORST reduction observed."""
    seeds = [0] if quick else [0, 1, 2, 3]
    points = [run_prefetch_ab_point(s, quick) for s in seeds]
    worst = min(p["stall_reduction_x"] for p in points)
    return {
        "points": points,
        "layout_stall_s": points[0]["layout_stall_s"],
        "predicted_stall_s": points[0]["predicted_stall_s"],
        "stall_reduction_x": points[0]["stall_reduction_x"],
        "min_stall_reduction_x": float(worst),
        "bit_identical": all(p["bit_identical"] for p in points),
    }


def run_capacity(quick: bool = False) -> dict:
    """CXL budget sized for ~2 of 4 snapshots' hot regions: later publishes
    must clock-demote LRU victims (or spill their own hot set) and every
    snapshot must keep restoring bit-identically — alloc never fails."""
    n_hot, n_cold = (128, 64) if quick else (256, 128)
    pool = HierarchicalPool(cxl_capacity=256 << 20, rdma_capacity=1 << 30)
    per_snap_cxl = (n_hot + 16) * PAGE_SIZE
    master = PoolMaster(pool, cxl_budget=int(2.5 * per_snap_cxl))
    images = {}
    for i in range(4):
        rng = np.random.default_rng(100 + i)
        img = StateImage.build({
            "params": rng.standard_normal(n_hot * PAGE_SIZE // 4).astype(np.float32),
            "runtime": rng.integers(1, 7, (n_cold * PAGE_SIZE,)).astype(np.uint8),
        })
        rec = AccessRecorder(img.manifest)
        rec.touch_array("params")
        images[f"cap{i}"] = img
        master.publish(f"cap{i}", img, rec.working_set())
    orch = Orchestrator("cap-host", pool, master.catalog)
    bit = {}
    hot_pages = {}
    for i in range(4):
        ri = orch.restore(f"cap{i}")
        ri.engine.install_all_sync()
        bit[f"cap{i}"] = bool(np.array_equal(ri.instance.image.buf,
                                             images[f"cap{i}"].buf))
        hot_pages[f"cap{i}"] = ri.borrow.regions.n_hot
        ri.shutdown()
    orch.close()
    report = master.capacity.report()
    return {
        "budget_report": report,
        "n_hot_by_snapshot": hot_pages,
        "all_bit_identical": all(bit.values()),
        "alloc_failures": 0,          # reaching here means none were raised
        "demoted_or_degraded": int(report["demotions"] + report["degraded"]),
    }


def run(quick: bool = False) -> dict:
    adaptive = run_adaptive(quick=quick)
    prefetch_ab = run_prefetch_ab(quick=quick)
    capacity = run_capacity(quick=quick)
    criteria = {
        "recovery_ge_1_3x": bool(adaptive["recovery_x"] >= 1.3),
        "all_restores_bit_identical": bool(adaptive["all_bit_identical"]
                                           and capacity["all_bit_identical"]
                                           and prefetch_ab["bit_identical"]),
        "recuration_happened": adaptive["snapshot"]["recurated"]["version"] >= 1,
        "capacity_managed": capacity["demoted_or_degraded"] >= 1,
        "predicted_stall_cut_ge_2x":
            bool(prefetch_ab["min_stall_reduction_x"] >= 2.0),
    }
    out = {"adaptive": adaptive, "prefetch_ab": prefetch_ab,
           "capacity": capacity, "criteria": criteria, "quick": quick}
    OUT.mkdir(exist_ok=True)
    name = "adaptive_bench_quick.json" if quick else "adaptive_bench.json"
    (OUT / name).write_text(json.dumps(out, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI smoke (small image)")
    args = ap.parse_args()
    out = run(quick=args.quick)
    a = out["adaptive"]
    print(f"v0 hot={a['snapshot']['v0']['n_hot']} -> re-curated "
          f"hot={a['snapshot']['recurated']['n_hot']} "
          f"(drift={a['snapshot']['drift_pages']}, "
          f"unused={a['snapshot']['unused_hot_pages']})")
    print(f"first-invocation modeled latency: frozen "
          f"{a['frozen_first_invocation_s']*1e3:.3f} ms -> adaptive "
          f"{a['adaptive_first_invocation_s']*1e3:.3f} ms")
    print(f"restore-to-first-response: frozen {a['frozen_e2e_s']*1e3:.3f} ms "
          f"-> adaptive {a['adaptive_e2e_s']*1e3:.3f} ms "
          f"({a['recovery_x']:.2f}x recovery)")
    ab = out["prefetch_ab"]
    print(f"prefetch A/B: layout stall {ab['layout_stall_s']*1e3:.3f} ms -> "
          f"predicted {ab['predicted_stall_s']*1e3:.3f} ms "
          f"(min reduction over sweep: {ab['min_stall_reduction_x']:.2f}x)")
    print(f"capacity: {out['capacity']['budget_report']}")
    ok = all(out["criteria"].values())
    print(f"criteria: {out['criteria']}  ->  {'PASS' if ok else 'FAIL'}")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
