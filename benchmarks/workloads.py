"""The nine serverless workloads (Table 2 analogues) as model-serving
instances over real framework state.

Each workload is a reduced-config model server whose paged state image holds:
  * model params (always read by an invocation → hot, except embedding rows);
  * a `runtime` segment (guest kernel + Python + libs analogue: non-zero
    bytes of which only a small, scattered fraction is touched → the cold
    mass of §2.3.3);
  * a KV-cache arena + activation workspace (zero at snapshot time → the
    zero-page mass; positions written during an invocation are its dirtied
    pages — ffmpeg-style zero-pages-in-working-set arise here);
  * for MoE archs, expert hotness is structural: only routed experts' pages
    are touched.

Page classes are MEASURED by the real profiler + zero-detector, not assumed;
only segment sizing is calibrated so compositions span the paper's observed
range (zero 46.9%–90.7%, Fig. 3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core import StateImage
from repro.core.profiler import AccessRecorder, WorkloadProfile
from repro.models.model_zoo import build
from repro.serve.strategies import WorkloadSpec

PAPER_INSTANCE_BYTES = 1.5 * (1 << 30)   # Azure default 1.5 GiB (§2.3.3)


@dataclasses.dataclass(frozen=True)
class WorkloadDef:
    name: str
    arch: str
    domain: str
    runtime_mb: int          # non-zero runtime/libs segment
    arena_mb: int            # KV arena (zero at snapshot)
    workspace_mb: int        # activation workspace (zero at snapshot)
    runtime_touch_frac: float  # fraction of runtime pages touched / invocation
    prompt_len: int          # arena rows written per invocation
    arena_rows: int          # arena leading dim
    compute_s: float         # modeled function execution compute
    arena_touch_extra: float = 0.0  # extra arena (zero-page) churn → ffmpeg


# Calibrated so the MEASURED compositions span the paper's Fig-3 range
# (zero 46.9%–90.7%, hot ≈5.5% avg, cold = bulk of non-zero).
WORKLOADS: Dict[str, WorkloadDef] = {w.name: w for w in [
    WorkloadDef("chameleon",   "qwen2.5-14b",        "web",        24, 88, 44, 0.18, 128, 1024, 0.08),
    WorkloadDef("compression", "phi4-mini-3.8b",     "web",        22, 100, 40, 0.15, 96, 1024, 0.25),
    WorkloadDef("json",        "qwen2.5-32b",        "web",        18, 120, 56, 0.12, 64, 1024, 0.04),
    WorkloadDef("ffmpeg",      "seamless-m4t-medium","multimedia", 26, 84, 36, 0.18, 192, 1024, 0.90,
                arena_touch_extra=0.62),
    WorkloadDef("image",       "qwen2-vl-72b",       "multimedia", 24, 84, 40, 0.16, 128, 1024, 0.18),
    WorkloadDef("matmul",      "mistral-large-123b", "scientific", 30, 64, 32, 0.20, 96, 1024, 1.00),
    WorkloadDef("pagerank",    "zamba2-2.7b",        "scientific", 24, 92, 40, 0.15, 96, 1024, 0.45),
    WorkloadDef("pyaes",       "xlstm-125m",         "scientific", 10, 140, 56, 0.10, 32, 1024, 1.30),
    WorkloadDef("recognition", "deepseek-v3-671b",   "ml",         52, 26, 12, 0.30, 128, 1024, 2.00),
]}


@dataclasses.dataclass
class BuiltWorkload:
    wdef: WorkloadDef
    image: StateImage
    profile: WorkloadProfile
    invocation_touched: np.ndarray     # pages touched by one (measured) invocation
    scale: float

    def spec(self) -> WorkloadSpec:
        return WorkloadSpec(
            name=self.wdef.name,
            image=self.image,
            working_set=self.profile.working_set,
            touched=self.invocation_touched,
            compute_s=self.wdef.compute_s,
            scale=self.scale,
        )


def _expert_elements(extent, layer: int, expert: int, n_layers: int, n_experts: int):
    per_layer = 1
    for d in extent.shape[1:]:
        per_layer *= d
    per_expert = per_layer // n_experts
    base = layer * per_layer + expert * per_expert
    return base, base + per_expert


def build_workload(name: str, seed: int = 0, n_invocations: int = 16) -> BuiltWorkload:
    wdef = WORKLOADS[name]
    cfg = get_config(wdef.arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    from repro.checkpoint.ckpt import flatten_state
    arrays = dict(flatten_state(params))
    rng = np.random.default_rng(seed)
    # runtime/libs bytes: low-entropy like real code+data pages (repeated
    # motifs over a small alphabet) so the zstd cold tier sees realistic input
    motifs = rng.integers(1, 64, (256, 1024), dtype=np.uint8)
    picks = rng.integers(0, 256, (wdef.runtime_mb << 10,))
    arrays["runtime"] = motifs[picks].reshape(-1)
    arena_cols = (wdef.arena_mb << 20) // (4 * wdef.arena_rows)
    arrays["kv_arena"] = np.zeros((wdef.arena_rows, arena_cols), np.float32)
    arrays["workspace"] = np.zeros((wdef.workspace_mb << 20) // 4, np.float32)
    image = StateImage.build(arrays)
    extents = image.manifest.by_name()

    moe_names = [n for n in arrays if "/moe/w" in n]
    n_moe_layers = extents[moe_names[0]].shape[0] if moe_names else 0

    def one_invocation(rec: AccessRecorder, i: int) -> List[int]:
        r = np.random.default_rng((seed, i))
        before = set(rec.pages)
        # 1) token embeddings: Zipf-distributed rows of the (padded) table
        toks = np.minimum(r.zipf(1.4, size=wdef.prompt_len) - 1, cfg.vocab - 1)
        rec.touch_rows("embed/table", np.unique(toks))
        # 2) layer weights: everything except embeddings and routed experts
        for n in arrays:
            if n.startswith(("embed",)) or n in ("runtime", "kv_arena", "workspace"):
                continue
            if "/moe/w" in n:
                continue
            rec.touch_array(n)
        # 3) MoE: only routed experts (top-k per layer, Zipf-hot experts)
        if moe_names:
            for l in range(n_moe_layers):
                hot_e = np.minimum(r.zipf(1.3, size=cfg.top_k) - 1, cfg.n_experts - 1)
                for n in moe_names:
                    for e in set(int(x) for x in hot_e):
                        lo, hi = _expert_elements(extents[n], l, e, n_moe_layers, cfg.n_experts)
                        rec.touch_elements(n, lo, hi)
        # 4) runtime/libs: scattered short spans (Fig-4 fragmentation).
        # 85% of spans come from a workload-stable rng — the same interpreter
        # and library pages every invocation — plus a small per-input tail,
        # so the cumulative working set stays bounded (paper Fig. 2/3).
        rt_pages = extents["runtime"].page_count
        n_touch = int(rt_pages * wdef.runtime_touch_frac)
        stable = np.random.default_rng((seed, 777))
        for src, frac in ((stable, 0.85), (r, 0.15)):
            starts = src.integers(0, max(1, rt_pages - 4),
                                  size=max(1, int(n_touch * frac) // 2))
            for s in starts:
                span = int(src.integers(1, 4))
                rec.touch_pages(range(extents["runtime"].first_page + s,
                                      extents["runtime"].first_page + s + span))
        # 5) KV arena: the request's cache slot. Slots are reused heavily
        # within a keep-alive window (one slot per in-flight request), with
        # an occasional fresh slot — the fresh slot's pages are the zero
        # pages a restored instance still faults on.
        stable2 = np.random.default_rng((seed, 778))
        rows = [int(stable2.integers(0, 14)), int(stable2.integers(0, 14))]
        if i % 4 == 0:
            rows.append(int(r.integers(14, wdef.arena_rows)))
        rec.touch_rows("kv_arena", sorted(set(rows)))
        if wdef.arena_touch_extra:
            extra = int(extents["kv_arena"].page_count * wdef.arena_touch_extra)
            ps = r.integers(0, extents["kv_arena"].page_count, size=extra)
            rec.touch_pages(extents["kv_arena"].first_page + ps)
        # 6) workspace: leading region reused every invocation
        rec.touch_elements("workspace", 0, min(arrays["workspace"].size,
                                               wdef.prompt_len * 4096))
        return sorted(set(rec.pages) - before)

    rec = AccessRecorder(image.manifest)
    for i in range(n_invocations):
        one_invocation(rec, i)
    profile = WorkloadProfile(name, n_invocations, rec.working_set())

    # The snapshot is taken AFTER the profiling invocations ran (§3.2): pages
    # they dirtied hold non-zero content (guest state, init_on_free=1 zeroes
    # only *freed* pages).  Fill the dirtied arena slots and the reused
    # workspace region so they classify as hot, exactly as in the paper —
    # ffmpeg's extra churn pages stay zero (freed+zeroed) though they are in
    # the WS, reproducing the paper's ffmpeg anomaly.
    fill = np.random.default_rng((seed, 779))
    arena = image.read_array("kv_arena").copy()
    stable2 = np.random.default_rng((seed, 778))
    dirtied_rows = sorted({int(stable2.integers(0, 14)) for _ in range(2 * n_invocations)})
    arena[dirtied_rows] = fill.standard_normal((len(dirtied_rows), arena.shape[1])).astype(np.float32)
    image.write_array("kv_arena", arena)
    ws_arr = image.read_array("workspace").copy()
    n_ws = min(ws_arr.size, wdef.prompt_len * 4096)
    ws_arr[:n_ws] = fill.standard_normal(n_ws).astype(np.float32)
    image.write_array("workspace", ws_arr)

    # the measured invocation: replay one more (not added to the profile —
    # its stable accesses are in the WS, its random tail is distribution
    # shift landing on cold/zero pages)
    rec2 = AccessRecorder(image.manifest)
    first_touched = one_invocation(rec2, n_invocations + 1)

    scale = PAPER_INSTANCE_BYTES / image.buf.nbytes
    return BuiltWorkload(wdef, image, profile,
                         np.asarray(first_touched, dtype=np.int64), scale)


_cache: Dict[str, BuiltWorkload] = {}


def get_workload(name: str) -> BuiltWorkload:
    if name not in _cache:
        _cache[name] = build_workload(name)
    return _cache[name]


def all_workloads() -> List[str]:
    return list(WORKLOADS)
