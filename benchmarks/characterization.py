"""Fig. 3 analogue: snapshot image composition across the 9 workloads.

Classes are measured with the real zero-detector + profiler over the built
instance images.  Also cross-checks the Pallas zero_detect kernel against
the numpy bitmap on a sample of each image.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.core.snapshot import classify_pages, _compress_cold
from .workloads import all_workloads, get_workload

OUT = Path(__file__).resolve().parents[1] / "experiments"


def run(verify_kernel: bool = True) -> dict:
    rows = []
    for name in all_workloads():
        bw = get_workload(name)
        classes = classify_pages(bw.image, bw.profile.working_set)
        s = classes.summary()
        total = s["total"]
        row = {
            "workload": name,
            "arch": bw.wdef.arch,
            "total_pages": total,
            "zero_frac": s["zero"] / total,
            "hot_frac": s["hot"] / total,
            "cold_frac": s["cold"] / total,
            "cold_frac_of_nonzero": s["cold"] / max(1, s["cold"] + s["hot"]),
            "image_mb": bw.image.buf.nbytes / (1 << 20),
        }
        if verify_kernel:
            from repro.kernels import zero_detect
            mat = bw.image.pages_matrix()[: 4096].view(np.float32)
            kb = np.asarray(zero_detect(mat, use_pallas=True, interpret=True)).astype(bool)
            nb = ~bw.image.pages_matrix()[: 4096].any(axis=1)
            row["kernel_bitmap_match"] = bool(np.array_equal(kb, nb))
        # beyond-paper: zstd cold-tier ratio (even sample of 2k cold pages)
        step = max(1, classes.cold_pages.size // 2048)
        cold = classes.cold_pages[::step][:2048]
        if cold.size:
            blob, _ = _compress_cold(bw.image.pages_matrix()[cold])
            row["cold_zstd_ratio"] = cold.size * 4096 / max(1, len(blob))
        rows.append(row)

    avg = {
        "zero_frac": float(np.mean([r["zero_frac"] for r in rows])),
        "hot_frac": float(np.mean([r["hot_frac"] for r in rows])),
        "cold_frac_of_nonzero": float(np.mean([r["cold_frac_of_nonzero"] for r in rows])),
        "cold_zstd_ratio": float(np.mean([r.get("cold_zstd_ratio", 1.0) for r in rows])),
    }
    out = {"rows": rows, "average": avg,
           "paper": {"zero_frac": 0.828, "hot_frac": 0.055,
                     "cold_frac_of_nonzero": 0.727,
                     "zero_range": [0.469, 0.907]}}
    OUT.mkdir(exist_ok=True)
    (OUT / "characterization.json").write_text(json.dumps(out, indent=2))
    return out


def main():
    out = run()
    print(f"{'workload':14s}{'total':>8s}{'zero':>8s}{'hot':>8s}{'cold':>8s}  kernel-ok")
    for r in out["rows"]:
        print(f"{r['workload']:14s}{r['total_pages']:8d}{r['zero_frac']:8.1%}"
              f"{r['hot_frac']:8.1%}{r['cold_frac']:8.1%}  {r.get('kernel_bitmap_match')}")
    a = out["average"]
    print(f"{'AVERAGE':14s}{'':8s}{a['zero_frac']:8.1%}{a['hot_frac']:8.1%}"
          f"   cold/nonzero={a['cold_frac_of_nonzero']:.1%}"
          f"   cold-zstd={a['cold_zstd_ratio']:.2f}x")
    p = out["paper"]
    print(f"{'PAPER':14s}{'':8s}{p['zero_frac']:8.1%}{p['hot_frac']:8.1%}"
          f"   cold/nonzero={p['cold_frac_of_nonzero']:.1%}")


if __name__ == "__main__":
    main()
