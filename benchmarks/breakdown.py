"""Fig. 6 analogue: invocation-time breakdown on chameleon across the five
restore configurations, at 32 concurrent restores (the paper's setting).

Also validates end-to-end restore correctness with REAL data movement: an
Aquifer restore through the published snapshot must be bit-identical.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.core import HierarchicalPool, Orchestrator, PoolMaster
from repro.serve.strategies import STRATEGIES, hot_preinstall_time, run_strategy
from .workloads import get_workload

OUT = Path(__file__).resolve().parents[1] / "experiments"


def run(workload: str = "chameleon", concurrency: int = 32) -> dict:
    bw = get_workload(workload)
    spec = bw.spec()

    rows = {}
    for strat in STRATEGIES:
        res = run_strategy(strat, spec, concurrency=concurrency)
        rows[strat] = {**res.breakdown(), "stats": res.stats}
    # per-page (non-coalesced) Aquifer for the run-batching ablation
    res_pp = run_strategy("aquifer", spec, concurrency=concurrency, batched=False)
    rows["aquifer_perpage"] = {**res_pp.breakdown(), "stats": res_pp.stats}

    # hot pre-install, per-instance serial path: the per-run vs per-page
    # modeled-time comparison the batched serving design targets
    pre_batched = hot_preinstall_time(spec, batched=True)
    pre_perpage = hot_preinstall_time(spec, batched=False)
    hot_preinstall = {
        "per_page_s": pre_perpage,
        "batched_s": pre_batched,
        "speedup": pre_perpage / max(pre_batched, 1e-12),
    }

    # real-data correctness: publish + borrow + full restore (run-coalesced
    # hot pre-install + background cold-extent prefetch), bit-compare
    pool = HierarchicalPool(cxl_capacity=1 << 30, rdma_capacity=2 << 30)
    master = PoolMaster(pool)
    master.publish(workload, bw.image, bw.profile.working_set)
    orch = Orchestrator("bench-host", pool, master.catalog, use_async_rdma=True,
                        prefetch_cold=True)
    ri = orch.restore(workload)
    assert ri is not None
    ri.engine.wait_prefetch_idle()
    for page in range(ri.instance.image.total_pages):
        if not ri.instance.present[page]:
            ri.engine.access(page)
    bit_identical = bool(np.array_equal(ri.instance.image.buf, bw.image.buf))
    inst_stats = dict(ri.instance.stats)
    prefetch_stats = dict(ri.engine.prefetch_stats)
    ledger = {k: v for k, v in ri.ledger.seconds.items()}
    ri.shutdown()

    fc, aq = rows["firecracker"]["total"], rows["aquifer"]["total"]
    fs = rows["faasnap"]["total"]
    out = {
        "workload": workload,
        "concurrency": concurrency,
        "breakdown": rows,
        "hot_preinstall": hot_preinstall,
        "install_cost_ratio_fc_over_aquifer":
            rows["firecracker"]["exec_install"] / max(rows["aquifer"]["exec_install"], 1e-12),
        "speedup_vs_firecracker": fc / aq,
        "speedup_vs_faasnap": fs / aq,
        "restore_bit_identical": bit_identical,
        "restore_instance_stats": inst_stats,
        "restore_prefetch_stats": prefetch_stats,
        "restore_modeled_ledger_s": ledger,
        "paper": {"speedup_vs_firecracker": 2.12, "speedup_vs_faasnap": 1.19,
                  "install_cost_ratio": 187.0},
    }
    OUT.mkdir(exist_ok=True)
    (OUT / "breakdown.json").write_text(json.dumps(out, indent=2))
    return out


def main():
    out = run()
    print(f"breakdown on {out['workload']} @ {out['concurrency']} concurrent (modeled s):")
    print(f"{'strategy':12s}{'setup':>9s}{'prefetch':>9s}{'install':>9s}{'compute':>9s}{'total':>9s}")
    for strat, r in out["breakdown"].items():
        print(f"{strat:12s}{r['setup']:9.4f}{r['prefetch']:9.4f}{r['exec_install']:9.4f}"
              f"{r['compute']:9.4f}{r['total']:9.4f}")
    print(f"Aquifer speedup vs firecracker: {out['speedup_vs_firecracker']:.2f}x (paper 2.12x)")
    print(f"Aquifer speedup vs faasnap:     {out['speedup_vs_faasnap']:.2f}x (paper 1.19x)")
    print(f"install-cost ratio fc/aquifer:  {out['install_cost_ratio_fc_over_aquifer']:.0f}x (paper 187x)")
    hp = out["hot_preinstall"]
    print(f"hot pre-install (per-instance): per-page {hp['per_page_s']*1e3:.2f} ms "
          f"vs batched {hp['batched_s']*1e3:.2f} ms -> {hp['speedup']:.2f}x")
    print(f"bit-identical restore: {out['restore_bit_identical']}")


if __name__ == "__main__":
    main()
