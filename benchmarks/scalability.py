"""Fig. 7 analogue: end-to-end invocation time vs concurrency (1..32) across
all nine workloads × five strategies, plus the headline geomean speedups.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.serve.strategies import STRATEGIES, hot_preinstall_time, run_strategy
from .workloads import all_workloads, get_workload

OUT = Path(__file__).resolve().parents[1] / "experiments"
CONCURRENCY = (1, 2, 4, 8, 12, 16, 24, 32)


def run() -> dict:
    results = {}
    preinstall = {}
    for name in all_workloads():
        spec = get_workload(name).spec()
        per = {}
        for strat in STRATEGIES:
            per[strat] = {str(n): run_strategy(strat, spec, concurrency=n).total_s
                          for n in CONCURRENCY}
        # run-batching ablation: Aquifer with strictly page-at-a-time installs
        per["aquifer_perpage"] = {
            str(n): run_strategy("aquifer", spec, concurrency=n, batched=False).total_s
            for n in CONCURRENCY}
        results[name] = per
        pp = hot_preinstall_time(spec, batched=False)
        bt = hot_preinstall_time(spec, batched=True)
        preinstall[name] = {"per_page_s": pp, "batched_s": bt,
                            "speedup": pp / max(bt, 1e-12)}

    # geomean speedups at n=32 (paper's headline setting)
    def geomean(xs):
        return float(np.exp(np.mean(np.log(xs))))

    speedups = {}
    for base in ("firecracker", "faasnap", "reap", "fctiered"):
        ratios = [results[w][base]["32"] / results[w]["aquifer"]["32"]
                  for w in results]
        speedups[f"vs_{base}"] = geomean(ratios)
    ratios_no_ffmpeg = [results[w]["reap"]["32"] / results[w]["aquifer"]["32"]
                        for w in results if w != "ffmpeg"]
    speedups["vs_reap_excl_ffmpeg"] = geomean(ratios_no_ffmpeg)
    fastest = {w: min(results[w], key=lambda s: results[w][s]["32"]) for w in results}

    out = {
        "results": results,
        "geomean_speedups_at_32": speedups,
        "fastest_strategy_per_workload": fastest,
        "hot_preinstall_per_page_vs_batched": preinstall,
        "paper": {"vs_firecracker": 2.2, "vs_faasnap": 1.3, "vs_reap": 1.1,
                  "note": "REAP beats Aquifer on ffmpeg (zero pages in WS)"},
    }
    OUT.mkdir(exist_ok=True)
    (OUT / "scalability.json").write_text(json.dumps(out, indent=2))
    return out


def main():
    out = run()
    print("end-to-end invocation time @concurrency=32 (modeled s):")
    print(f"{'workload':14s}" + "".join(f"{s:>13s}" for s in STRATEGIES))
    for w, per in out["results"].items():
        print(f"{w:14s}" + "".join(f"{per[s]['32']:13.3f}" for s in STRATEGIES))
    g = out["geomean_speedups_at_32"]
    print(f"\ngeomean speedup of Aquifer @32: vs firecracker {g['vs_firecracker']:.2f}x "
          f"(paper 2.2x) | vs faasnap {g['vs_faasnap']:.2f}x (paper 1.3x) | "
          f"vs reap {g['vs_reap']:.2f}x (paper 1.1x)")
    pre = out["hot_preinstall_per_page_vs_batched"]
    print("hot pre-install per-page vs batched (per-instance):")
    for w, r in pre.items():
        print(f"  {w:14s} {r['per_page_s']*1e3:8.2f} ms -> {r['batched_s']*1e3:8.2f} ms "
              f"({r['speedup']:.2f}x)")
    print(f"fastest per workload: {out['fastest_strategy_per_workload']}")


if __name__ == "__main__":
    main()
