"""Co-located restore concurrency benchmark: shared NodePageServer vs the
per-instance engine baseline (ISSUE 3 acceptance bench).

For each sweep point we publish snapshot(s), attach `conc` co-located
restores on ONE host, and drive every restore to full completion (hot
pre-install + zero ranges + background cold-extent prefetch) with REAL byte
movement through the pool emulation.  Two runtimes are compared:

  shared   : one host-wide AsyncRDMAEngine + completion worker + DRR
             prefetch pump for all restores, with hot-chunk / cold-extent
             fan-out across same-snapshot restores (core/nodeserver.py).
  perinst  : the legacy path — a private engine, completion thread and
             prefetcher per restore; each restore registers as its own
             stream on the host link arbiters, so its modeled time sees
             the same fair-share contention model.

Scenarios: `same` (all `conc` restores of ONE snapshot — the fan-out
regime) and `mixed` (each restore its own snapshot).  Per point we report
per-instance modeled restore time (p50/p99), aggregate modeled throughput
(restored bytes / modeled makespan), bit-identity of every restore, and
the worst relative error of the executed modeled time against the analytic
`strategies.modeled_concurrent_restore_s` (`_shared()`-based) model.

Acceptance (checked into the emitted json): at concurrency >= 8 same-
snapshot the shared runtime must show >= 1.5x aggregate modeled throughput
vs the baseline, every restore bit-identical, and executed modeled time
within 15% of the analytic model across the whole sweep.

Results land in experiments/concurrency_bench.json (full sweep) or
experiments/concurrency_bench_quick.json (--quick CI smoke, <= 5 s).
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time
from pathlib import Path

import numpy as np

from repro.core import (HierarchicalPool, LayoutOrderPolicy, Orchestrator,
                        PoolMaster, StateImage)
from repro.core.pagestore import PAGE_SIZE
from repro.core.profiler import AccessRecorder
from repro.serve.strategies import modeled_concurrent_restore_s

OUT = Path(__file__).resolve().parents[1] / "experiments"

FULL_CONCS_SAME = (1, 2, 4, 8, 16, 32)
FULL_CONCS_MIXED = (1, 2, 4, 8)
QUICK_CONCS_SAME = (1, 8)


def make_restore_image(seed: int = 0, hot_pages: int = 512,
                       cold_pages: int = 1536, zero_pages: int = 2048):
    """Snapshot-shaped image: contiguous hot params + a cold runtime mass
    with a few short hot spans (Fig-4 fragmentation) + a zero arena."""
    rng = np.random.default_rng(seed)
    arrays = {
        "params": rng.standard_normal(hot_pages * PAGE_SIZE // 4).astype(np.float32),
        "runtime": rng.integers(1, 7, (cold_pages * PAGE_SIZE,)).astype(np.uint8),
        "arena": np.zeros(zero_pages * PAGE_SIZE, np.uint8),
    }
    img = StateImage.build(arrays)
    rec = AccessRecorder(img.manifest)
    rec.touch_array("params")
    rt = img.manifest.by_name()["runtime"]
    for s in range(7, cold_pages - 4, max(8, cold_pages // 24)):
        rec.touch_pages(range(rt.first_page + s, rt.first_page + s + 2))
    return img, rec.working_set()


def run_point(conc: int, shared: bool, same_snapshot: bool, images,
              max_extent_pages: int = 64) -> dict:
    pool = HierarchicalPool(cxl_capacity=512 << 20, rdma_capacity=1 << 30)
    master = PoolMaster(pool)
    n_snaps = 1 if same_snapshot else conc
    for i in range(n_snaps):
        img, ws = images[i]
        master.publish(f"snap{i}", img, ws)
    policy = LayoutOrderPolicy(max_extent_pages)
    orch = Orchestrator("host0", pool, master.catalog, use_async_rdma=True,
                        use_node_server=shared, prefetch_policy=policy)
    # attach every restore BEFORE any page movement so all `conc` streams
    # contend for the whole restore window (the load balancer dispatching a
    # co-located burst), then drive them concurrently to completion
    ris = []
    for k in range(conc):
        ri = orch.restore(f"snap{0 if same_snapshot else k}",
                          pre_install=False, prefetch_cold=False)
        assert ri is not None
        ris.append(ri)
    errs = []

    def drive(ri):
        try:
            ri.engine.pre_install_hot()
            ri.engine.install_zero_runs()
            ri.engine.start_prefetcher(policy=policy)
            if not ri.engine.wait_prefetch_idle(120.0):
                raise TimeoutError("prefetch did not complete")
        except Exception as exc:            # pragma: no cover
            errs.append(exc)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=drive, args=(ri,)) for ri in ris]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall_s = time.perf_counter() - t0
    assert not errs, errs

    groups = 1 if (shared and same_snapshot) else conc
    times, model_errs, identical = [], [], True
    for k, ri in enumerate(ris):
        src = images[0 if same_snapshot else k][0]
        ok = bool(ri.instance.present.all()) and \
            bool(np.array_equal(ri.instance.image.buf, src.buf))
        identical = identical and ok
        t_exec = ri.ledger.total()
        t_model = modeled_concurrent_restore_s(ri.engine.reader, groups,
                                               max_extent_pages)
        times.append(t_exec)
        model_errs.append(abs(t_exec - t_model) / t_model)
    bytes_total = sum(images[0 if same_snapshot else k][0].buf.nbytes
                      for k in range(conc))
    makespan = max(times)
    for ri in ris:
        ri.shutdown()
    orch.close()
    times_a = np.asarray(times)
    return {
        "conc": conc,
        "mode": "shared" if shared else "perinst",
        "scenario": "same" if same_snapshot else "mixed",
        "restore_p50_ms": float(np.percentile(times_a, 50) * 1e3),
        "restore_p99_ms": float(np.percentile(times_a, 99) * 1e3),
        "restore_max_ms": float(makespan * 1e3),
        "agg_throughput_GBps": bytes_total / makespan / 1e9,
        "model_err_max": float(max(model_errs)),
        "bit_identical": identical,
        "wall_s": wall_s,
    }


def run(quick: bool = False) -> dict:
    kw = dict(hot_pages=256, cold_pages=512, zero_pages=768) if quick else {}
    concs_same = QUICK_CONCS_SAME if quick else FULL_CONCS_SAME
    concs_mixed = () if quick else FULL_CONCS_MIXED
    n_images = max((1,) + tuple(concs_mixed))
    images = [make_restore_image(seed=i, **kw) for i in range(n_images)]

    rows = []
    for conc in concs_same:
        for shared in (False, True):
            rows.append(run_point(conc, shared, same_snapshot=True, images=images))
    for conc in concs_mixed:
        for shared in (False, True):
            rows.append(run_point(conc, shared, same_snapshot=False, images=images))

    def tput(conc, mode, scen):
        return next(r["agg_throughput_GBps"] for r in rows
                    if r["conc"] == conc and r["mode"] == mode
                    and r["scenario"] == scen)

    gains = {c: tput(c, "shared", "same") / tput(c, "perinst", "same")
             for c in concs_same}
    model_err_max = max(r["model_err_max"] for r in rows)
    criteria = {
        "gain_same_snapshot_by_conc": {str(c): g for c, g in gains.items()},
        "gain_at_conc_ge_8": min((g for c, g in gains.items() if c >= 8),
                                 default=None),
        "gain_ok": all(g >= 1.5 for c, g in gains.items() if c >= 8),
        "model_err_max": model_err_max,
        "model_within_15pct": model_err_max <= 0.15,
        "all_bit_identical": all(r["bit_identical"] for r in rows),
    }
    out = {"rows": rows, "criteria": criteria, "quick": quick}
    OUT.mkdir(exist_ok=True)
    name = "concurrency_bench_quick.json" if quick else "concurrency_bench.json"
    (OUT / name).write_text(json.dumps(out, indent=2))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2-point same-snapshot smoke (CI fast tier, <=5s)")
    args = ap.parse_args()
    out = run(quick=args.quick)
    hdr = (f"{'conc':>5s} {'scenario':>9s} {'mode':>8s} {'p50(ms)':>9s} "
           f"{'p99(ms)':>9s} {'agg GB/s':>9s} {'model err':>10s}  ok")
    print(hdr)
    for r in out["rows"]:
        print(f"{r['conc']:5d} {r['scenario']:>9s} {r['mode']:>8s} "
              f"{r['restore_p50_ms']:9.2f} {r['restore_p99_ms']:9.2f} "
              f"{r['agg_throughput_GBps']:9.2f} {r['model_err_max']:10.3f}  "
              f"{r['bit_identical']}")
    c = out["criteria"]
    print(f"\nshared-vs-perinst same-snapshot gain: "
          + ", ".join(f"{k}x{v:.2f}" for k, v in
                      c["gain_same_snapshot_by_conc"].items()))
    print(f"gain at conc>=8 >= 1.5x: {c['gain_ok']}   "
          f"model within 15%: {c['model_within_15pct']} "
          f"(max err {c['model_err_max']:.3f})   "
          f"all bit-identical: {c['all_bit_identical']}")
    # CI gate: a corruption or throughput/model regression must fail the job
    if not (c["gain_ok"] and c["model_within_15pct"] and c["all_bit_identical"]):
        sys.exit(1)


if __name__ == "__main__":
    main()
