"""Kernel benchmark + calibration for the snapshot data plane (DESIGN.md §13).

Three layers, cleanly separated so CI can gate what is deterministic:

* **modeled** — roofline byte-math for the piecemeal op sequence vs the fused
  ops at a canonical workload (tier-independent), via
  ``roofline.analysis.movement_roofline``.  Pure arithmetic ⇒ bit-equal
  across runs; these are the keys ``check_regressions.py`` gates at ±10%.
* **measured** — wall-clock with the timing discipline the old bench lacked:
  first call (compile) timed separately, then warm steady-state reps with
  ``jax.block_until_ready``, GB/s reported.  ``--quick`` runs the Pallas
  kernels in interpret mode at tiny shapes (fast CI tier, no TPU); the
  default tier runs the dispatch path (compiled Pallas on TPU, jit'd oracle
  elsewhere) at large shapes (nightly).  Wall-clock is informational — this
  box is not the target — and is never gated.
* **calibration** — ``--write-calibration`` derives per-page constants from
  the fused ops' *actual* per-invocation traffic at the platform HBM roof
  and writes ``experiments/kernel_calibration.json``; ``serve/strategies.py``
  sources ``CHECKSUM_BW`` / ``PUBLISH_SWEEP_PAGE_S`` / ``PREINSTALL_PAGE_S``
  from the committed copy at import (file-read only, never re-measured).

The bench also asserts fused-vs-piecemeal bit-identity on the shapes it
times (``criteria.bit_identical``) and reports the Python/dispatch overhead
fraction of each path — the tentpole's "both hot paths bandwidth-bound, with
the Python-overhead fraction reported" line.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core.pagestore import PAGE_SIZE
from repro.kernels import (
    fused_publish,
    fused_restore,
    page_checksum,
    page_gather,
    page_scatter,
    zero_detect,
)
from repro.roofline.analysis import HBM_BW, movement_roofline

OUT = Path(__file__).resolve().parents[1] / "experiments"

# Canonical modeled workload — tier-independent so the gated modeled keys are
# bit-equal between the quick CI run and the committed baseline: a 256 MiB
# image, 1/3 zero pages, working set = half of the non-zero pages; restore
# pre-installs a 64 MiB hot chunk.
MODEL_N = 65536
MODEL_ZERO = MODEL_N // 3
MODEL_HOT = (MODEL_N - MODEL_ZERO) // 2
MODEL_COLD = MODEL_N - MODEL_ZERO - MODEL_HOT
MODEL_CHUNK = 16384


# -- modeled tier (gated) -----------------------------------------------------
def publish_traffic(n: int, n_hot: int, n_cold: int):
    """(read, written) HBM bytes per op for the piecemeal publish sequence
    that produces the fused op's full output contract (zero bitmap, guest-
    indexed checksum table, compacted hot/cold, dedup hashes), vs the fused
    single sweep.  int32 bitmap and u32 checksums are 4 B/page."""
    p, nz = PAGE_SIZE, n_hot + n_cold
    piecemeal = {
        "zero_detect": (n * p, 4 * n),
        "page_checksum": (n * p, 4 * n),
        "gather_hot": (n_hot * p, n_hot * p),
        "gather_cold": (n_cold * p, n_cold * p),
        "dedup_hash": (nz * p, 4 * nz),
    }
    fused = (n * p, nz * p + 8 * n)
    return piecemeal, fused


def restore_traffic(m: int):
    """Piecemeal pre-install (gather → checksum → scatter) vs the fused
    gather→verify→scatter kernel, per chunk of ``m`` pages."""
    p = PAGE_SIZE
    piecemeal = {
        "page_gather": (m * p, m * p),
        "page_checksum": (m * p, 4 * m),
        "page_scatter": (m * p, m * p),
    }
    fused = (m * p, m * p + 4 * m)
    return piecemeal, fused


def _modeled_pair(piecemeal: dict, fused_rw) -> dict:
    ops = [movement_roofline(k, r, w) for k, (r, w) in piecemeal.items()]
    fused = movement_roofline("fused", *fused_rw)
    piece_s = sum(o["bound_s"] for o in ops)
    speedup = piece_s / fused["bound_s"]
    return {
        "piecemeal_s": piece_s,
        "fused_s": fused["bound_s"],
        "speedup": speedup,
        "speedup_ge_2": bool(speedup >= 2.0),
        "piecemeal_ops": ops,
        "fused": fused,
    }


def modeled_section() -> dict:
    pub = _modeled_pair(*publish_traffic(MODEL_N, MODEL_HOT, MODEL_COLD))
    res = _modeled_pair(*restore_traffic(MODEL_CHUNK))
    return {
        "workload": {"n_pages": MODEL_N, "n_zero": MODEL_ZERO,
                     "n_hot": MODEL_HOT, "n_cold": MODEL_COLD,
                     "chunk_pages": MODEL_CHUNK, "hbm_bw_Bps": HBM_BW},
        "publish": pub,
        "restore": res,
    }


def calibration_section(modeled: dict) -> dict:
    """Per-page constants for serve/strategies.py, derived from the fused
    sweeps' actual traffic at the platform HBM roof (deterministic)."""
    csum = movement_roofline("page_checksum", PAGE_SIZE, 4)
    return {
        "written_by": "benchmarks/kernel_bench.py --write-calibration",
        "note": "per-page data-plane costs at the v5e HBM roofline; "
                "serve/strategies.py reads `constants` at import "
                "(DESIGN.md §13)",
        "platform": {"hbm_bw_Bps": HBM_BW},
        "per_page": {
            "checksum_bytes": PAGE_SIZE + 4,
            "publish_sweep_bytes":
                modeled["publish"]["fused"]["bytes_total"] / MODEL_N,
            "preinstall_bytes":
                modeled["restore"]["fused"]["bytes_total"] / MODEL_CHUNK,
        },
        "constants": {
            "checksum_bw_Bps": PAGE_SIZE / csum["bound_s"],
            "publish_sweep_page_s": modeled["publish"]["fused_s"] / MODEL_N,
            "preinstall_page_s": modeled["restore"]["fused_s"] / MODEL_CHUNK,
        },
    }


def calibration_in_sync(cal: dict) -> bool:
    """Do the constants strategies.py loaded (from the *committed* artifact)
    match what this bench derives now?  Flips the gated boolean if someone
    changes kernel traffic without recommitting the artifact."""
    from repro.serve import strategies

    loaded = {
        "checksum_bw_Bps": strategies.CHECKSUM_BW,
        "publish_sweep_page_s": strategies.PUBLISH_SWEEP_PAGE_S,
        "preinstall_page_s": strategies.PREINSTALL_PAGE_S,
    }
    want = cal["constants"]
    return all(abs(loaded[k] - want[k]) <= 1e-9 * abs(want[k]) for k in want)


# -- measured tier (informational) --------------------------------------------
def _time(fn, reps: int):
    """(first_call_s, steady_s): first call includes trace+compile; steady
    is the mean of ``reps`` warm calls, each blocked to completion."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    first = time.perf_counter() - t0
    jax.block_until_ready(fn())  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return first, (time.perf_counter() - t0) / reps


def _mk_workload(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    pages = rng.integers(0, 256, size=(n, PAGE_SIZE), dtype=np.uint8)
    pages[::3] = 0  # every 3rd page zero
    ws = np.zeros(n, dtype=bool)
    ws[rng.choice(n, size=n // 2, replace=False)] = True
    u32 = pages.view(np.uint32).reshape(n, -1)
    return pages, u32, ws


def measured_section(tier: str) -> dict:
    """tier='interpret': real Pallas kernels in interpret mode, tiny shapes.
    tier='dispatch': default dispatch (compiled Pallas on TPU, jit'd oracle
    elsewhere), larger shapes."""
    if tier == "interpret":
        n, m, reps = 64, 16, 2
        disp = {"use_pallas": True, "interpret": True}
        blk = {"block_pages": 8}
    else:
        n, m, reps = 8192, 2048, 5
        disp = {}
        blk = {}
    pages, u32, ws = _mk_workload(n)
    rows = []

    def bench(name, fn, nbytes):
        first, steady = _time(fn, reps)
        rows.append({
            "kernel": name, "tier": tier, "bytes": nbytes,
            "first_call_s": first, "steady_s": steady,
            "steady_GBps": nbytes / steady / 1e9,
            "modeled_tpu_s": nbytes / HBM_BW,
        })
        return steady

    # per-kernel rows (satellite: compile/steady split + GB/s)
    bench("zero_detect", lambda: zero_detect(u32, **disp, **blk), u32.nbytes)
    bench("page_checksum", lambda: page_checksum(pages, **disp, **blk),
          pages.nbytes)
    zb = np.asarray(zero_detect(u32, **disp, **blk)) != 0
    hot_idx = np.flatnonzero(~zb & ws).astype(np.int32)
    cold_idx = np.flatnonzero(~zb & ~ws).astype(np.int32)
    bench("page_gather", lambda: page_gather(u32, hot_idx, **disp),
          2 * hot_idx.size * PAGE_SIZE)
    chunk = np.asarray(page_gather(u32, hot_idx, **disp))
    dst = np.sort(hot_idx)
    src = np.arange(dst.size, dtype=np.int32)
    dest0 = np.zeros_like(u32)
    bench("page_scatter", lambda: page_scatter(dest0, chunk, dst, **disp),
          2 * dst.size * PAGE_SIZE)

    # fused vs piecemeal: publish
    def piecemeal_publish():
        zb_ = np.asarray(zero_detect(u32, **disp, **blk)) != 0
        csum = np.asarray(page_checksum(pages, **disp, **blk))
        hi = np.flatnonzero(~zb_ & ws).astype(np.int32)
        ci = np.flatnonzero(~zb_ & ~ws).astype(np.int32)
        hot = np.asarray(page_gather(u32, hi, **disp))
        cold = np.asarray(page_gather(u32, ci, **disp))
        hhash = np.asarray(page_checksum(hot, **disp, **blk))
        chash = np.asarray(page_checksum(cold, **disp, **blk))
        return zb_, csum, hot, cold, hhash, chash

    def do_fused_publish():
        return fused_publish(pages, ws, **disp, **blk)

    nz_bytes = (hot_idx.size + cold_idx.size) * PAGE_SIZE
    pm_bytes = 2 * n * PAGE_SIZE + 2 * nz_bytes + nz_bytes
    fu_bytes = n * PAGE_SIZE + nz_bytes
    pm_pub = bench("publish_piecemeal", piecemeal_publish, pm_bytes)
    fu_pub = bench("publish_fused", do_fused_publish, fu_bytes)

    # fused vs piecemeal: restore pre-install
    m = min(m, dst.size)
    chunk_m, src_m, dst_m = chunk[:m], src[:m], dst[:m]
    chunk_b = np.ascontiguousarray(chunk_m).view(np.uint8)
    dest_b = np.zeros(n * PAGE_SIZE, np.uint8).reshape(n, PAGE_SIZE)

    def piecemeal_restore():
        g = np.asarray(page_gather(chunk_m, src_m, **disp))
        cs = np.asarray(page_checksum(g, **disp, **blk))
        out = page_scatter(dest0, g, dst_m, **disp)
        return cs, out

    def do_fused_restore():
        return fused_restore(dest_b, chunk_b, dst_m, src_indices=src_m, **disp)

    pm_res = bench("restore_piecemeal", piecemeal_restore, 5 * m * PAGE_SIZE)
    fu_res = bench("restore_fused", do_fused_restore, 2 * m * PAGE_SIZE)

    # bit-identity of the two paths on the timed shapes (untimed)
    zb_, csum, hot, cold, hhash, chash = piecemeal_publish()
    fp = do_fused_publish()
    f_out, f_csums = do_fused_restore()
    f_out_u32 = np.asarray(f_out).reshape(n, PAGE_SIZE).view(np.uint32)
    p_csums, p_out = piecemeal_restore()
    identical = bool(
        np.array_equal(fp.zero_bitmap, zb_)
        and np.array_equal(fp.checksums, np.asarray(csum))
        and np.array_equal(fp.hot.view(np.uint32).reshape(hot.shape), hot)
        and np.array_equal(fp.cold.view(np.uint32).reshape(cold.shape), cold)
        and np.array_equal(fp.checksums[hot_idx], hhash)
        and np.array_equal(fp.checksums[cold_idx], chash)
        and np.array_equal(f_csums, p_csums)
        and np.array_equal(f_out_u32.reshape(n, -1), np.asarray(p_out))
    )

    # Python/dispatch overhead: steady time at a 1-page shape is ~pure
    # per-call overhead; its fraction of the full-shape steady time says how
    # far each path is from bandwidth-bound on this backend.
    p1, _, w1 = _mk_workload(3)
    _, pm1 = _time(lambda: fused_publish(p1, w1, use_pallas=False), reps)
    n_pm_ops = 6  # zero + csum + 2x gather + 2x hash dispatches
    overhead = {
        "per_dispatch_s": pm1,
        "publish_piecemeal_fraction": min(1.0, n_pm_ops * pm1 / pm_pub),
        "publish_fused_fraction": min(1.0, pm1 / fu_pub),
        "restore_piecemeal_fraction": min(1.0, 3 * pm1 / pm_res),
        "restore_fused_fraction": min(1.0, pm1 / fu_res),
    }
    return {
        "tier": tier, "backend": jax.default_backend(),
        "n_pages": n, "chunk_pages": int(m), "reps": reps,
        "per_kernel": rows,
        "publish": {"piecemeal_steady_s": pm_pub, "fused_steady_s": fu_pub,
                    "speedup": pm_pub / fu_pub},
        "restore": {"piecemeal_steady_s": pm_res, "fused_steady_s": fu_res,
                    "speedup": pm_res / fu_res},
        "python_overhead": overhead,
        "bit_identical": identical,
    }


# -- driver -------------------------------------------------------------------
def run(quick: bool = False, write_calibration: bool = False) -> dict:
    modeled = modeled_section()
    cal = calibration_section(modeled)
    measured = measured_section("interpret" if quick else "dispatch")
    out = {
        "config": {"tier": "quick" if quick else "full",
                   "backend": jax.default_backend()},
        "modeled": modeled,
        "measured": measured,
        "criteria": {
            "bit_identical": measured["bit_identical"],
            "calibration_in_sync": calibration_in_sync(cal),
            "publish_speedup_ge_2": modeled["publish"]["speedup_ge_2"],
            "restore_speedup_ge_2": modeled["restore"]["speedup_ge_2"],
        },
    }
    OUT.mkdir(exist_ok=True)
    (OUT / "kernel_bench.json").write_text(json.dumps(out, indent=2))
    if write_calibration:
        (OUT / "kernel_calibration.json").write_text(json.dumps(cal, indent=2))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="interpret-mode sweep at tiny shapes (fast CI tier)")
    ap.add_argument("--write-calibration", action="store_true",
                    help="write experiments/kernel_calibration.json")
    args = ap.parse_args(argv)
    out = run(quick=args.quick, write_calibration=args.write_calibration)

    mo, me = out["modeled"], out["measured"]
    print(f"tier={out['config']['tier']} backend={out['config']['backend']}")
    for r in me["per_kernel"]:
        print(f"  {r['kernel']:20s} first={r['first_call_s'] * 1e3:8.2f}ms  "
              f"steady={r['steady_s'] * 1e3:8.2f}ms  "
              f"{r['steady_GBps']:7.2f} GB/s")
    for op in ("publish", "restore"):
        print(f"{op}: modeled {mo[op]['speedup']:.2f}x "
              f"(piecemeal {mo[op]['piecemeal_s'] * 1e3:.3f}ms -> "
              f"fused {mo[op]['fused_s'] * 1e3:.3f}ms), "
              f"measured {me[op]['speedup']:.2f}x steady-state")
    print(f"criteria: {out['criteria']}")
    return 0 if all(out["criteria"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
