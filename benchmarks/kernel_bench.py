"""Kernel-level benchmark: snapshot-pipeline kernels' modeled TPU time vs the
CPU-oracle wall time, plus the roofline-relevant bytes-per-page math.

On TPU these walks are HBM-bandwidth-bound; the modeled time is
bytes / 819 GB/s (v5e HBM) with the kernel's actual tiling. The CPU wall
time column is informational only (this box is not the target).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.kernels import page_checksum, page_gather, zero_detect

HBM_BW = 819e9
OUT = Path(__file__).resolve().parents[1] / "experiments"


def run(n_pages: int = 8192) -> dict:
    rng = np.random.default_rng(0)
    pages = rng.standard_normal((n_pages, 1024)).astype(np.float32)
    pages[:: 3] = 0.0
    rows = []

    def bench(name, fn, nbytes, reps=3):
        fn()  # warm compile
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        wall = (time.perf_counter() - t0) / reps
        rows.append({
            "kernel": name,
            "bytes": nbytes,
            "cpu_wall_s": wall,
            "modeled_tpu_s": nbytes / HBM_BW,
            "modeled_tpu_GBps": nbytes / (nbytes / HBM_BW) / 1e9,
        })

    nbytes = pages.nbytes
    bench("zero_detect", lambda: np.asarray(zero_detect(pages)), nbytes)
    idx = rng.choice(n_pages, size=n_pages // 3, replace=False).astype(np.int32)
    bench("page_gather", lambda: np.asarray(page_gather(pages, idx)),
          idx.size * 4096 * 2)
    pb = pages[: 2048].view(np.uint8).reshape(2048, -1)[:, :4096].copy()
    bench("page_checksum", lambda: np.asarray(page_checksum(pb)), pb.nbytes)

    out = {"rows": rows, "note": "modeled = bytes/819GBps (v5e HBM-bound walk)"}
    OUT.mkdir(exist_ok=True)
    (OUT / "kernel_bench.json").write_text(json.dumps(out, indent=2))
    return out


def main():
    out = run()
    for r in out["rows"]:
        print(f"{r['kernel']:14s}"
              f"bytes={r['bytes']/1e6:8.1f}MB  cpu={r['cpu_wall_s']*1e3:7.2f}ms  "
              f"modeled-tpu={r['modeled_tpu_s']*1e6:7.1f}us")


if __name__ == "__main__":
    main()
