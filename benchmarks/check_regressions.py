"""Benchmark-regression CI gate (ISSUE 4 satellite).

Re-runs the quick benchmark suite in-process and compares the modeled-time
/ throughput keys below against the *committed* baselines under
``experiments/`` (read via ``git show HEAD:...`` so an earlier CI step that
rewrote the working-tree files cannot launder a regression).  Any key
drifting beyond its tolerance — or any boolean correctness key flipping —
fails the gate with a non-zero exit.

Previously only ``concurrency_bench`` self-checked its acceptance criteria;
``breakdown`` and ``serving_bench`` smoke steps could silently regress.
This is the single gate over all of them, wired as the last fast-tier CI
step.

Usage:
    python -m benchmarks.check_regressions            # re-run + compare
    python -m benchmarks.check_regressions --no-run   # compare disk files
    python -m benchmarks.check_regressions --baseline-dir DIR   # tests

All compared keys are modeled/deterministic (re-running the benches twice
produces bit-equal values — wall-clock keys are never compared), so the
±10% default tolerance only absorbs genuine algorithmic drift.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

REPO = Path(__file__).resolve().parents[1]
EXPERIMENTS = REPO / "experiments"

DEFAULT_TOLERANCE = 0.10

# Key spec: dotted path into the benchmark's JSON (integers index lists);
# optionally (path, tolerance).  Booleans compare exactly.
KeySpec = Union[str, Tuple[str, float]]

BASELINES: Dict[str, List[KeySpec]] = {
    "breakdown.json": [
        "breakdown.firecracker.total",
        "breakdown.reap.total",
        "breakdown.faasnap.total",
        "breakdown.fctiered.total",
        "breakdown.aquifer.total",
        "breakdown.aquifer_perpage.total",
        "hot_preinstall.speedup",
        "speedup_vs_firecracker",
        "speedup_vs_faasnap",
        "restore_bit_identical",
    ],
    "serving_bench.json": [
        "rows.0.modes.per_page.total_modeled_s",
        "rows.0.modes.batched.total_modeled_s",
        "rows.0.modes.batched.preinstall_modeled_s",
        "rows.0.preinstall_speedup",
        "rows.0.total_speedup",
        "all_bit_identical_and_not_slower",
    ],
    "concurrency_bench_quick.json": [
        "rows.0.restore_p50_ms",
        "rows.0.agg_throughput_GBps",
        "rows.1.restore_p50_ms",
        "rows.1.agg_throughput_GBps",
        "rows.2.restore_p50_ms",
        "rows.2.agg_throughput_GBps",
        "rows.3.restore_p50_ms",
        "rows.3.agg_throughput_GBps",
        "criteria.all_bit_identical",
        "criteria.model_within_15pct",
    ],
    "adaptive_bench_quick.json": [
        "adaptive.frozen_first_invocation_s",
        "adaptive.frozen_e2e_s",
        "adaptive.adaptive_e2e_s",
        "adaptive.recovery_x",
        "criteria.recovery_ge_1_3x",
        "criteria.all_restores_bit_identical",
        "criteria.recuration_happened",
        "criteria.capacity_managed",
        # predictive prefetch A/B (ISSUE 10): paced-drain residual stalls
        # are modeled-deterministic, so ±10% only absorbs real drift
        "prefetch_ab.layout_stall_s",
        "prefetch_ab.predicted_stall_s",
        "prefetch_ab.stall_reduction_x",
        "prefetch_ab.bit_identical",
        "criteria.predicted_stall_cut_ge_2x",
    ],
    "dedup_bench_quick.json": [
        "effective_capacity_x",
        "dedup.unique_byte_ratio",
        "dedup.publish_modeled_s",
        "dedup.restore_modeled_s",
        "dedup.exec_restore_total_s",
        "baseline.publish_modeled_s",
        "criteria.capacity_x_ge_1_5",
        "criteria.all_restores_bit_identical",
        "criteria.i6_consistent",
        "criteria.dedup_worthwhile",
    ],
    # fleet serving (DESIGN.md §14): cold-start tails and hit fractions are
    # discrete-event results on modeled restore costs under a fixed seed —
    # bit-reproducible, so drift means placement/economics actually changed
    "fleet_bench_quick.json": [
        "pod.mean_shared_base_frac",
        "pod.probe_marginal_bytes_total",
        "policies.locality.p50_cold_start_s",
        "policies.locality.p99_cold_start_s",
        "policies.locality.throughput_rps",
        "policies.locality.warm_frac",
        "policies.locality.join_frac",
        "policies.random.p99_cold_start_s",
        "policies.round_robin.p99_cold_start_s",
        "locality_vs_random_p99_x",
        "criteria.locality_vs_random_p99_ge_1_3x",
        "criteria.bit_deterministic",
        "criteria.restores_bit_identical",
        "criteria.profile_matches_restore_model",
        "criteria.all_completed",
    ],
    # multi-pod topology (DESIGN.md §16): same discrete-event determinism as
    # fleet_bench_quick; drift means the replica planner, the fabric
    # surcharge, or the migration-economics gate actually changed
    "fleet_bench_multipod_quick.json": [
        "tiers.single_pod.p99_cold_start_s",
        "tiers.no_replication.p99_cold_start_s",
        "tiers.replicated.p99_cold_start_s",
        "tiers.replicated.p50_cold_start_s",
        "single_vs_replicated_p99_x",
        "replication_plan.replicas_added",
        "replication_plan.skipped_uneconomic",
        "criteria.replicated_beats_single_pod_p99",
        "criteria.replicated_beats_no_replication_p99",
        "criteria.economics_gate_filtered",
        "criteria.bit_deterministic",
        "criteria.restores_bit_identical",
        "criteria.all_completed",
    ],
    # fused data plane (DESIGN.md §13): the modeled keys are roofline byte-
    # math at a canonical workload — deterministic, so drift means the kernel
    # sequence's traffic actually changed; wall-clock keys are never gated
    "kernel_bench.json": [
        "modeled.publish.piecemeal_s",
        "modeled.publish.fused_s",
        "modeled.publish.speedup",
        "modeled.restore.piecemeal_s",
        "modeled.restore.fused_s",
        "modeled.restore.speedup",
        "criteria.bit_identical",
        "criteria.calibration_in_sync",
        "criteria.publish_speedup_ge_2",
        "criteria.restore_speedup_ge_2",
    ],
    # fault tolerance (DESIGN.md §15): all sweeps run under VirtualClock
    # with seeded fault schedules, so every modeled key is bit-reproducible;
    # the overhead key holds the armed fault seam to exactly-zero cost
    "fault_bench_quick.json": [
        "sweeps.none.p50_modeled_ms",
        # exact-zero baseline: any nonzero fresh value is an infinite
        # relative drift, so the armed seam staying free is gated twice
        # (here numerically, below as a boolean criterion)
        "fault_free_overhead_pct",
        "sweeps.rdma_timeouts.p50_modeled_ms",
        "sweeps.rdma_timeouts.total_retries",
        "sweeps.cxl_poison.p50_modeled_ms",
        "sweeps.cxl_poison.total_repairs",
        "sweeps.brownout.p50_modeled_ms",
        "degraded_model.degraded_ms",
        "criteria.fault_free_overhead_zero",
        "criteria.all_bit_identical",
        "criteria.retries_recovered",
        "criteria.repairs_happened",
        "criteria.brownout_degrades_not_fails",
        "criteria.degraded_costs_more",
        "criteria.degraded_model_within_15pct",
    ],
}


def get_path(obj, path: str):
    cur = obj
    for part in path.split("."):
        if isinstance(cur, list):
            cur = cur[int(part)]
        else:
            cur = cur[part]
    return cur


def compare(name: str, baseline: dict, fresh: dict,
            keys: Sequence[KeySpec],
            tolerance: float = DEFAULT_TOLERANCE) -> List[str]:
    """Violation messages for every key that regressed beyond tolerance."""
    violations: List[str] = []
    for spec in keys:
        path, tol = (spec, tolerance) if isinstance(spec, str) else spec
        try:
            base = get_path(baseline, path)
        except (KeyError, IndexError, TypeError):
            violations.append(f"{name}: baseline is missing key {path!r}")
            continue
        try:
            new = get_path(fresh, path)
        except (KeyError, IndexError, TypeError):
            violations.append(f"{name}: fresh run is missing key {path!r}")
            continue
        if isinstance(base, bool) or isinstance(new, bool):
            if bool(base) != bool(new):
                violations.append(
                    f"{name}: {path} flipped {base!r} -> {new!r}")
            continue
        base_f, new_f = float(base), float(new)
        denom = max(abs(base_f), 1e-12)
        rel = abs(new_f - base_f) / denom
        if rel > tol:
            violations.append(
                f"{name}: {path} drifted {rel:+.1%} beyond ±{tol:.0%} "
                f"(baseline {base_f:.6g}, now {new_f:.6g})")
    return violations


def load_baseline(fname: str, baseline_dir: Optional[Path] = None) -> dict:
    """The committed baseline: ``git show HEAD:experiments/<fname>`` so a
    working-tree overwrite by an earlier bench step cannot mask drift;
    ``baseline_dir`` overrides for tests / non-git checkouts."""
    if baseline_dir is not None:
        return json.loads((Path(baseline_dir) / fname).read_text())
    proc = subprocess.run(
        ["git", "-C", str(REPO), "show", f"HEAD:experiments/{fname}"],
        capture_output=True, text=True)
    if proc.returncode == 0:
        return json.loads(proc.stdout)
    # non-git fallback: the on-disk file (warn — it may have been rewritten)
    path = EXPERIMENTS / fname
    if not path.exists():
        raise FileNotFoundError(
            f"no committed baseline for {fname} (git show failed: "
            f"{proc.stderr.strip()!r}) and {path} does not exist")
    print(f"warning: using working-tree {path} as baseline (not in git)",
          file=sys.stderr)
    return json.loads(path.read_text())


def run_fresh() -> Dict[str, dict]:
    """Re-run the quick benches in-process; returns results keyed like
    BASELINES.  (Each run() also rewrites its experiments/*.json, which is
    why baselines are read from git, not disk.)"""
    from . import (adaptive_bench, breakdown, concurrency_bench, dedup_bench,
                   fault_bench, fleet_bench, kernel_bench, serving_bench)

    return {
        "breakdown.json": breakdown.run(),
        "serving_bench.json": serving_bench.run(["chameleon"]),
        "concurrency_bench_quick.json": concurrency_bench.run(quick=True),
        "adaptive_bench_quick.json": adaptive_bench.run(quick=True),
        "dedup_bench_quick.json": dedup_bench.run(quick=True),
        "kernel_bench.json": kernel_bench.run(quick=True),
        "fleet_bench_quick.json": fleet_bench.run(quick=True),
        "fleet_bench_multipod_quick.json": fleet_bench.run_multipod(quick=True),
        "fault_bench_quick.json": fault_bench.run(quick=True),
    }


def check_all(fresh: Dict[str, dict],
              baseline_dir: Optional[Path] = None,
              tolerance: float = DEFAULT_TOLERANCE) -> List[str]:
    violations: List[str] = []
    for fname, keys in BASELINES.items():
        if fname not in fresh:
            violations.append(f"{fname}: no fresh result produced")
            continue
        try:
            baseline = load_baseline(fname, baseline_dir)
        except (FileNotFoundError, json.JSONDecodeError) as e:
            violations.append(f"{fname}: cannot load baseline ({e})")
            continue
        violations.extend(compare(fname, baseline, fresh[fname], keys,
                                  tolerance))
    return violations


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-run", action="store_true",
                    help="compare the on-disk experiments/*.json instead of "
                         "re-running the quick benches")
    ap.add_argument("--baseline-dir", type=Path, default=None,
                    help="read baselines from this directory instead of "
                         "`git show HEAD:experiments/`")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE)
    args = ap.parse_args(argv)

    if args.no_run:
        fresh = {f: json.loads((EXPERIMENTS / f).read_text())
                 for f in BASELINES if (EXPERIMENTS / f).exists()}
    else:
        fresh = run_fresh()
    violations = check_all(fresh, baseline_dir=args.baseline_dir,
                           tolerance=args.tolerance)
    n_keys = sum(len(k) for k in BASELINES.values())
    if violations:
        print(f"REGRESSION GATE FAILED — {len(violations)} violation(s) "
              f"across {n_keys} checked keys:")
        for v in violations:
            print(f"  ✗ {v}")
        return 1
    print(f"regression gate OK: {n_keys} keys across {len(BASELINES)} "
          f"baselines within ±{args.tolerance:.0%}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
