"""Generate the EXPERIMENTS.md roofline/dry-run tables from the JSON cells.

    PYTHONPATH=src python experiments/make_report.py > experiments/tables.md
"""
import glob
import json
import os
import sys

HERE = os.path.dirname(os.path.abspath(__file__))


def load(pattern, best=False):
    out = {}
    for f in sorted(glob.glob(os.path.join(HERE, "dryrun", pattern))):
        d = json.load(open(f))
        key = (d["arch"], d["shape"])
        if best and key in out and out[key]["status"] == "ok" and d["status"] == "ok":
            def bound(x):
                r = x["roofline"]
                return max(r["compute_s"], r["memory_s"], r["collective_s"])
            if bound(d) >= bound(out[key]):
                continue
        out[key] = d
    return out


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.1f}"


def roofline_table(cells, title):
    print(f"\n### {title}\n")
    print("| arch | shape | dom | compute s | memory s | collective s | "
          "C/bound | useful | arg GiB | temp GiB |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape), d in sorted(cells.items()):
        if d["status"] == "skipped":
            print(f"| {arch} | {shape} | SKIP(full-attn) | | | | | | | |")
            continue
        if d["status"] != "ok":
            print(f"| {arch} | {shape} | ERROR | | | | | | | |")
            continue
        r = d["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"]) or 1e-12
        print(f"| {arch} | {shape} | {r['dominant']} | {r['compute_s']:.4f} "
              f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
              f"| {r['compute_s']/bound:.2f} | {min(r['useful_flops_fraction'],9.99):.2f} "
              f"| {fmt_bytes(d['memory']['argument_bytes'])} "
              f"| {fmt_bytes(d['memory']['temp_bytes'])} |")


def compare_table(base, opt, title):
    print(f"\n### {title}\n")
    print("| arch | shape | bound before → after | Δ | dominant before → after |")
    print("|---|---|---|---|---|")
    for key in sorted(base):
        b, o = base[key], opt.get(key)
        if b["status"] != "ok" or not o or o["status"] != "ok":
            continue
        rb, ro = b["roofline"], o["roofline"]
        bb = max(rb["compute_s"], rb["memory_s"], rb["collective_s"]) or 1e-12
        bo = max(ro["compute_s"], ro["memory_s"], ro["collective_s"]) or 1e-12
        tag = o.get("_file", "")
        print(f"| {key[0]} | {key[1]} | {bb:.3f}s → {bo:.3f}s | {bb/bo:.2f}x "
              f"| {rb['dominant']} → {ro['dominant']} |")


def main():
    pod = load("*__pod.json")
    mp = load("*__multipod.json")
    opt = load("*__pod@*.json", best=True)
    # merge opt variants: prefer the all-knob sweep results
    roofline_table(pod, "Single-pod (16x16) baseline roofline — all 40 cells")
    if opt:
        compare_table(pod, opt, "Baseline vs optimized (seq_parallel + "
                                "attn_batch_shard + mla_absorb), single pod")
    print("\n### Multi-pod (2x16x16) compile proof\n")
    print("| arch | shape | status | compile s | arg GiB | temp GiB |")
    print("|---|---|---|---|---|---|")
    for (arch, shape), d in sorted(mp.items()):
        if d["status"] == "skipped":
            print(f"| {arch} | {shape} | SKIP(full-attn) | | | |")
        elif d["status"] == "ok":
            print(f"| {arch} | {shape} | ok | {d['compile_s']} "
                  f"| {fmt_bytes(d['memory']['argument_bytes'])} "
                  f"| {fmt_bytes(d['memory']['temp_bytes'])} |")
        else:
            print(f"| {arch} | {shape} | ERROR | | | |")


if __name__ == "__main__":
    sys.exit(main())
